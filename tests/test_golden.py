"""Golden-trace conformance suite (ISSUE 4).

``tests/golden/*.json`` holds generator-engine reference results — cycles,
outputs, FIFO table digests, query/forced-false stats, plus a depth-variant
record — for every taxonomy + dynamic design in the corpus below.  Each
test asserts that *every* engine path reproduces its design's reference
exactly:

  * ``generator``     — ``simulate(trace="never")`` (the reference itself);
  * ``auto``          — whatever ``trace="auto"`` selects (straight-line
                        trace, periodized hybrid, or generator fallback);
  * ``hybrid``        — ``simulate_hybrid(periodize=False)``, per-query;
  * ``periodized``    — ``simulate_hybrid(periodize=True)``, burst path;
  * ``resimulate`` / ``resimulate_batch`` — the depth-variant record;
  * ``sweep service`` — ``repro.sweep.SweepService`` over the same depth
                        variants: bit-identical for any block split,
                        duplicate rows, arrival order or cache state.

Future refactors therefore cannot silently drift any path.  Intentional
behavior changes are refreshed with one auditable command (the diff of the
JSON files is the review artifact)::

    PYTHONPATH=src python -m pytest -m golden --regen-golden
    # or: PYTHONPATH=src python tests/golden/regen.py
"""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core import resimulate, resimulate_batch, simulate
from repro.core.trace import TraceUnsupported, simulate_hybrid
from repro.designs.dynamic import (fig2_poll_burst, multisite_poll,
                                   nb_success_stream, watchdog_pipe)
from repro.designs.paper import PAPER_DESIGNS
from repro.designs.typea import (fir_filter, high_latency_pipe,
                                 merge_sort_staged, parallel_loops,
                                 producer_consumer, skynet_like)
from repro.sweep import FaultInjector, RetryPolicy, SweepService

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

# Small, fast instances — tier-1 runs the whole corpus on every path.
GOLDEN_DESIGNS = {
    # the paper's Type B/C designs (Table 4)
    "fig4_ex2": lambda: PAPER_DESIGNS["fig4_ex2"](n=96),
    "fig4_ex3": lambda: PAPER_DESIGNS["fig4_ex3"](n=96),
    "fig4_ex4a": lambda: PAPER_DESIGNS["fig4_ex4a"](n=96),
    "fig4_ex4a_d": lambda: PAPER_DESIGNS["fig4_ex4a_d"](n=96),
    "fig4_ex4b": lambda: PAPER_DESIGNS["fig4_ex4b"](n=96),
    "fig4_ex4b_d": lambda: PAPER_DESIGNS["fig4_ex4b_d"](n=96),
    "fig4_ex5": lambda: PAPER_DESIGNS["fig4_ex5"](n=96),
    "fig2_timer": lambda: PAPER_DESIGNS["fig2_timer"](n=96),
    "deadlock": lambda: PAPER_DESIGNS["deadlock"](n=16),
    "branch": lambda: PAPER_DESIGNS["branch"](prog_len=128),
    "multicore": lambda: PAPER_DESIGNS["multicore"](cores=4, prog_len=32),
    # dynamic designs beyond the paper
    "watchdog_pipe": lambda: watchdog_pipe(items=96, stages=2, depth=4,
                                           poll_gap=16),
    "fig2_poll_burst": lambda: fig2_poll_burst(items=96, stages=2, depth=4),
    "multisite_poll": lambda: multisite_poll(items=96, depth=16),
    "nb_success_stream": lambda: nb_success_stream(items=96, depth=16),
    # Type A taxonomy designs (straight-line trace path)
    "producer_consumer": lambda: producer_consumer(n=64),
    "fir_filter": lambda: fir_filter(n=96, taps=4),
    "parallel_loops": lambda: parallel_loops(n=64),
    "merge_sort_staged": lambda: merge_sort_staged(log_n=4),
    "skynet_like": lambda: skynet_like(items=96, depth=8),
    "high_latency_pipe": lambda: high_latency_pipe(items=24, stages=3,
                                                   ii=16),
}


def _normalize(obj):
    """JSON-stable view: tuples -> lists, recursively, sorted dict keys."""
    if isinstance(obj, dict):
        return {str(k): _normalize(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        return [_normalize(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    return obj


def _fifo_digest(result) -> str:
    """Order-insensitive digest of every FIFO table's end state (commit
    times per side + leftover payloads)."""
    h = hashlib.sha256()
    for tbl in result.graph.fifos:
        h.update(np.sort(np.asarray(tbl.write_times, np.int64)).tobytes())
        h.update(b"|")
        h.update(np.sort(np.asarray(tbl.read_times, np.int64)).tobytes())
        h.update(b"|")
        h.update(repr(list(tbl.values)).encode())
        h.update(b"#")
    return h.hexdigest()


def _record(result) -> dict:
    """The conformance record every engine path must reproduce."""
    return {
        "cycles": int(result.cycles),
        "deadlock": bool(result.deadlock),
        "deadlock_cycle": int(result.deadlock_cycle),
        "outputs": _normalize(result.outputs),
        "fifo_digest": _fifo_digest(result),
        "n_constraints": len(result.constraints),
        "stats": {
            "nodes": int(result.stats.nodes),
            "edges": int(result.stats.edges),
            "queries": int(result.stats.queries),
            "queries_forced_false": int(result.stats.queries_forced_false),
            "skipped_probes": int(result.stats.skipped_probes),
        },
    }


def reference_record(name: str) -> dict:
    """Build a design's golden record from the generator engine."""
    builder = GOLDEN_DESIGNS[name]
    base = simulate(builder(), trace="never")
    rec = _record(base)
    rec["depths"] = [int(d) for d in base.depths]
    try:
        simulate_hybrid(builder())
        rec["hybrid_supported"] = True
    except TraceUnsupported:
        rec["hybrid_supported"] = False
    if not base.deadlock:
        dv = tuple(d + 1 for d in base.depths)
        var = simulate(builder(), depths=dv, trace="never")
        rec["variant_depths"] = list(dv)
        rec["variant"] = {
            "cycles": int(var.cycles),
            "deadlock": bool(var.deadlock),
            "outputs": _normalize(var.outputs),
        }
    return rec


def golden_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, f"{name}.json")


def write_golden(name: str) -> dict:
    rec = reference_record(name)
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    with open(golden_path(name), "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
        f.write("\n")
    return rec


def regen_all() -> None:
    for name in sorted(GOLDEN_DESIGNS):
        rec = write_golden(name)
        print(f"wrote golden/{name}.json  cycles={rec['cycles']} "
              f"deadlock={rec['deadlock']}")


@pytest.mark.golden
@pytest.mark.parametrize("name", sorted(GOLDEN_DESIGNS))
def test_golden_conformance(name, regen_golden):
    if regen_golden:
        write_golden(name)
        return
    path = golden_path(name)
    assert os.path.exists(path), (
        f"missing golden reference {path} — run "
        f"`python -m pytest -m golden --regen-golden` and commit the diff")
    with open(path) as f:
        golden = json.load(f)
    core = {k: golden[k] for k in ("cycles", "deadlock", "deadlock_cycle",
                                   "outputs", "fifo_digest", "n_constraints",
                                   "stats")}
    builder = GOLDEN_DESIGNS[name]

    g = simulate(builder(), trace="never")
    assert _record(g) == core, f"{name}: generator path drifted"
    assert [int(d) for d in g.depths] == golden["depths"], name

    a = simulate(builder(), trace="auto")
    assert _record(a) == core, f"{name}: auto path ({a.engine}) drifted"

    try:
        hp = simulate_hybrid(builder(), periodize=True)
        hybrid_supported = True
    except TraceUnsupported:
        hybrid_supported = False
    assert hybrid_supported == golden["hybrid_supported"], name
    if hybrid_supported:
        assert _record(hp) == core, f"{name}: periodized-hybrid drifted"
        hn = simulate_hybrid(builder(), periodize=False)
        assert _record(hn) == core, f"{name}: hybrid (per-query) drifted"

    if "variant" in golden:
        dv = tuple(golden["variant_depths"])
        vref = golden["variant"]
        inc = resimulate(a, dv)
        assert int(inc.result.cycles) == vref["cycles"], name
        assert bool(inc.result.deadlock) == vref["deadlock"], name
        assert _normalize(inc.result.outputs) == vref["outputs"], name
        D = np.asarray([dv, golden["depths"]], dtype=np.int64)
        out = resimulate_batch(g, D)
        assert int(out.cycles[0]) == vref["cycles"], name
        assert int(out.cycles[1]) == golden["cycles"], name

        # sparse jax lane differential: solver verdicts bit-identical to
        # numpy, including a depth-1 row that may deadlock or cycle
        Dj = np.asarray([dv, golden["depths"], [1] * len(dv)],
                        dtype=np.int64)
        o_np = resimulate_batch(g, Dj, backend="numpy", fallback=False)
        o_jx = resimulate_batch(g, Dj, backend="jax", fallback=False)
        assert (o_np.status == o_jx.status).all(), \
            f"{name}: jax status {o_jx.status} != numpy {o_np.status}"
        assert (o_np.cycles == o_jx.cycles).all(), \
            f"{name}: jax cycles {o_jx.cycles} != numpy {o_np.cycles}"
        assert (o_np.violated == o_jx.violated).all(), name

        # sweep service: duplicate rows, tiny blocks, warm-cache repeat
        # with reversed arrival order, then a one-block split — all must
        # reproduce the same reference numbers bit-for-bit
        D3 = np.asarray([dv, golden["depths"], dv], dtype=np.int64)
        with SweepService(block=2, shards=2, autostart=False) as svc:
            s1 = svc.sweep(g, D3)
            assert int(s1.cycles[0]) == vref["cycles"], name
            assert int(s1.cycles[1]) == golden["cycles"], name
            assert int(s1.cycles[2]) == vref["cycles"], name
            assert _normalize(s1.results[0].outputs) == vref["outputs"], name
            assert bool(s1.results[0].deadlock) == vref["deadlock"], name
            assert _normalize(s1.results[1].outputs) == golden["outputs"], \
                name
            s2 = svc.sweep(g, D3[::-1])          # warm + reversed arrival
            assert (s2.cycles == s1.cycles[::-1]).all(), name
            assert (s2.status == s1.status[::-1]).all(), name
        with SweepService(block=64, autostart=False) as svc:
            s3 = svc.sweep(g, D3)                # different block split
            assert (s3.cycles == s1.cycles).all(), name
            assert (s3.status == s1.status).all(), name

        # recovery must not bend verdicts: with the first shard solve
        # faulting (injected, deterministic) and retried, every delivered
        # row is still bit-identical to the fault-free run
        inj = FaultInjector(seed=1).arm("shard.fault", at=[0])
        with SweepService(block=2, shards=2, autostart=False,
                          injector=inj,
                          retry=RetryPolicy(max_attempts=3,
                                            backoff_s=0.0)) as svc:
            s4 = svc.sweep(g, D3)
            assert (s4.cycles == s1.cycles).all(), name
            assert (s4.status == s1.status).all(), name
            assert svc.scheduler.stats()["retries"] >= 1, name
            assert svc.scheduler.stats()["faulted_rows"] == 0, name


def test_golden_corpus_is_complete():
    """Every design in the corpus has a committed reference, and no stale
    reference file outlives its design.  (corpus_seeds.json is the
    random-corpus seed list, owned by tests/test_corpus.py.)"""
    have = {f[:-5] for f in os.listdir(GOLDEN_DIR) if f.endswith(".json")}
    have.discard("corpus_seeds")
    assert have == set(GOLDEN_DESIGNS), (
        f"golden corpus mismatch: missing={sorted(set(GOLDEN_DESIGNS) - have)} "
        f"stale={sorted(have - set(GOLDEN_DESIGNS))} — run "
        f"`python -m pytest -m golden --regen-golden` and commit the diff")


def test_golden_corpus_covers_all_engine_paths():
    """The corpus must exercise the straight-line trace, the hybrid and the
    generator-fallback paths under trace="auto"."""
    engines = set()
    for name, builder in GOLDEN_DESIGNS.items():
        engines.add(simulate(builder(), trace="auto").engine)
    assert engines == {"omnisim", "omnisim-trace", "omnisim-hybrid"}
