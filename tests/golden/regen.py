#!/usr/bin/env python
"""Regenerate the golden-trace reference files in this directory.

Usage (from the repo root)::

    PYTHONPATH=src python tests/golden/regen.py

Equivalent to ``python -m pytest -m golden --regen-golden``.  The rewritten
``tests/golden/*.json`` diff is the review artifact for any intentional
behavior change — commit it alongside the change that caused it.
"""
import os
import sys

_TESTS_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _TESTS_DIR)

import test_golden  # noqa: E402

if __name__ == "__main__":
    test_golden.regen_all()
