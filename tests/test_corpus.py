"""Corpus generator + conformance sweep tests.

Tier-1 (fast slice, runs in the default ``pytest -x -q``):

  * determinism — ``generate(seed, scale)`` rebuilds the bit-identical
    Program (``program_fingerprint`` equality) and identical metadata;
  * structural invariants — every FIFO has exactly one writer and one
    reader, module count tracks the ``scale`` knob;
  * declared taxonomy matches ``classify_dynamic``;
  * a seed sweep of small designs through the full 7-path differential
    conformance runner (generator / auto / hybrid / periodized /
    resimulate / resimulate_batch / sweep);
  * a pinned seed list (``tests/golden/corpus_seeds.json``) — cycles,
    deadlock verdict and FIFO digest per ``(seed, scale)``, refreshed
    with ``--regen-golden`` like the rest of the golden suite;
  * a 300-module design end-to-end through ``simulate`` and the sweep
    service (the ISSUE's scale acceptance gate).

Opt-in big tiers:

  * ``-m corpus`` — the 100+-module sweep; size it with
    ``--corpus-seeds N --corpus-scale M``;
  * ``-m rtl``    — the sampled RTL-oracle cross-check.
"""
import json
import os

import numpy as np
import pytest

from repro.core import simulate
from repro.core.taxonomy import classify_dynamic
from repro.core.trace import program_fingerprint
from repro.corpus import (BENCH_SPEC, BLOCKING_SPEC, DEFAULT_SPEC,
                          check_conformance, fifo_digest, generate,
                          rtl_crosscheck)

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")
SEEDS_PATH = os.path.join(GOLDEN_DIR, "corpus_seeds.json")

#: the checked-in seed list: every (seed, scale) pinned in corpus_seeds.json
PINNED = [(seed, scale) for scale in (10, 32) for seed in range(8)]


# ---------------------------------------------------------------------------
# generator: determinism + structure
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,scale", [(0, 10), (3, 32), (1, 100)])
def test_regenerate_is_bit_identical(seed, scale):
    a = generate(seed, scale=scale)
    b = generate(seed, scale=scale)
    assert a.meta == b.meta
    assert program_fingerprint(a.builder()) == program_fingerprint(
        b.builder())
    # and the simulated artifacts agree too, not just the static hash
    ra, rb = simulate(a.builder()), simulate(b.builder())
    assert ra.cycles == rb.cycles
    assert fifo_digest(ra) == fifo_digest(rb)


@pytest.mark.parametrize("scale", [10, 32, 100])
def test_scale_knob_tracks_module_count(scale):
    for seed in range(4):
        c = generate(seed, scale=scale)
        assert scale <= c.meta["modules"] <= scale + 16
        assert c.meta["modules"] == len(c.builder().modules)


@pytest.mark.parametrize("spec", [DEFAULT_SPEC, BLOCKING_SPEC, BENCH_SPEC],
                         ids=["default", "blocking", "bench"])
def test_structural_invariants(spec):
    for seed in range(4):
        c = generate(seed, scale=24, spec=spec)
        c.validate()                     # SPSC + full connectivity
        assert len(c.meta["clusters"]) >= 1
        assert c.meta["fifos"] == len(c.builder().fifos)


def test_different_seeds_differ():
    fps = {program_fingerprint(generate(s, scale=24).builder())
           for s in range(6)}
    assert len(fps) == 6


def test_declared_taxonomy_matches_dynamic_classification():
    for seed in range(6):
        c = generate(seed, scale=24)
        cls = classify_dynamic(c.builder)
        assert cls.dtype == c.meta["declared"], (
            f"{c.name}: declared {c.meta['declared']} but classified "
            f"{cls.dtype}")
        assert cls.has_nonblocking == c.meta["has_nb"]


def test_blocking_spec_has_no_nb():
    for seed in range(4):
        c = generate(seed, scale=24, spec=BLOCKING_SPEC)
        assert not c.meta["has_nb"]
        assert c.meta["declared"] in ("A", "B")


# ---------------------------------------------------------------------------
# conformance: fast tier-1 slice
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(8))
def test_conformance_small(seed):
    for scale in (10, 32):
        c = generate(seed, scale=scale)
        check_conformance(c.builder, name=c.name)


def test_conformance_blocking_spec():
    for seed in range(4):
        c = generate(seed, scale=24, spec=BLOCKING_SPEC)
        check_conformance(c.builder, name=c.name)


def test_starved_designs_deadlock_conformantly():
    spec = DEFAULT_SPEC.replace(starve_prob=0.5)
    deadlocks = 0
    for seed in range(8):
        c = generate(seed, scale=24, spec=spec)
        rep = check_conformance(c.builder, name=c.name)
        deadlocks += rep.deadlock
    assert deadlocks >= 1          # the knob actually produces deadlocks
    assert deadlocks < 8           # ... but not unconditionally


# ---------------------------------------------------------------------------
# pinned seed list (golden)
# ---------------------------------------------------------------------------
def _seed_record(seed, scale):
    c = generate(seed, scale=scale)
    g = simulate(c.builder(), trace="never")
    return {
        "seed": seed, "scale": scale,
        "modules": c.meta["modules"], "fifos": c.meta["fifos"],
        "declared": c.meta["declared"],
        "cycles": int(g.cycles), "deadlock": bool(g.deadlock),
        "fifo_digest": fifo_digest(g),
    }


@pytest.mark.golden
def test_corpus_seed_list(regen_golden):
    records = [_seed_record(seed, scale) for seed, scale in PINNED]
    if regen_golden:
        with open(SEEDS_PATH, "w") as f:
            json.dump(records, f, indent=1, sort_keys=True)
            f.write("\n")
        pytest.skip(f"rewrote {os.path.basename(SEEDS_PATH)} "
                    f"({len(records)} entries)")
    assert os.path.exists(SEEDS_PATH), (
        "corpus_seeds.json missing — run: PYTHONPATH=src python -m pytest "
        "tests/test_corpus.py -m golden --regen-golden")
    with open(SEEDS_PATH) as f:
        want = json.load(f)
    assert records == want


# ---------------------------------------------------------------------------
# scale acceptance: a 300-module design end-to-end (tier-1)
# ---------------------------------------------------------------------------
def test_300_module_design_end_to_end():
    c = generate(2, scale=300)
    assert c.meta["modules"] >= 300
    g = simulate(c.builder(), trace="auto")
    assert not g.deadlock
    assert g.cycles > 0

    from repro.sweep import SweepService
    dv = tuple(int(d) + 1 for d in g.depths)
    D = np.asarray([dv, [int(d) for d in g.depths]], dtype=np.int64)
    svc = SweepService(block=16, shards=2, autostart=False)
    try:
        s = svc.sweep(g, D)
        assert int(s.cycles[1]) == int(g.cycles)
        assert not s.results[1].deadlock
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# big tiers: -m corpus (100+-module sweep) and -m rtl (oracle cross-check)
# ---------------------------------------------------------------------------
def pytest_generate_tests(metafunc):
    if "big_seed" in metafunc.fixturenames:
        n = metafunc.config.getoption("--corpus-seeds")
        metafunc.parametrize("big_seed", range(n))


@pytest.mark.corpus
def test_conformance_at_scale(big_seed, corpus_scale):
    c = generate(big_seed, scale=corpus_scale)
    rep = check_conformance(c.builder, name=c.name)
    assert rep.ok


@pytest.mark.corpus
def test_conformance_1000_modules():
    c = generate(0, scale=1000)
    rep = check_conformance(c.builder, name=c.name)
    assert rep.ok
    assert c.meta["modules"] >= 1000


@pytest.mark.rtl
def test_rtl_crosscheck_sampled():
    # >= 10 corpus designs must agree with the cycle-stepped RTL oracle —
    # outputs AND exact cycle counts (deadlock verdicts for dead designs)
    cases = ([(s, 10) for s in range(6)] + [(s, 32) for s in range(6)]
             + [(0, 100), (2, 300)])
    for seed, scale in cases:
        c = generate(seed, scale=scale)
        r = rtl_crosscheck(c.builder)
        assert r["agree"], f"{c.name}: engine vs RTL oracle disagree: {r}"


@pytest.mark.rtl
def test_rtl_crosscheck_starved():
    spec = DEFAULT_SPEC.replace(starve_prob=0.5)
    seen_deadlock = False
    for seed in range(4):
        c = generate(seed, scale=16, spec=spec)
        r = rtl_crosscheck(c.builder)
        assert r["agree"], f"{c.name}: {r}"
        seen_deadlock = seen_deadlock or r["deadlock"]
    assert seen_deadlock
