"""BENCH_core.json schema: benchmark refactors cannot silently drop keys.

Two layers (ISSUE 3 satellite):

  * tier-1: the *committed* ``BENCH_core.json`` must carry every required
    key with the right type — including every key the docs
    (``docs/dse_guide.md``) document, so docs and benchmarks cannot drift;
  * ``bench``-marked smoke: actually run ``benchmarks/run.py --quick`` into
    a temp file and validate the freshly-written output the same way.
"""
import json
import numbers
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# key -> required type; int-valued counters are exact, timings are floats
REQUIRED_KEYS = {
    # PR 1: depth-batched DSE trajectory
    "full_sim_us": numbers.Real,
    "looped_resimulate_us_per_config": numbers.Real,
    "batched_resimulate_us_per_config": numbers.Real,
    "batch_speedup_vs_loop": numbers.Real,
    "batch_K": numbers.Integral,
    "batch_reused": numbers.Integral,
    # PR 2: trace-compiled initial simulation
    "initial_sim_generator_us": numbers.Real,
    "initial_sim_trace_us": numbers.Real,
    "trace_replay_speedup_initial": numbers.Real,
    "trace_ops": numbers.Integral,
    "trace_ops_stored_after_periodization": numbers.Integral,
    # PR 3: hybrid segmented replay on dynamic designs
    "hybrid_replay_speedup_fig2_timer": numbers.Real,
    "hybrid_replay_speedup_branch": numbers.Real,
    "hybrid_replay_speedup_multicore": numbers.Real,
    "hybrid_replay_speedup_watchdog_pipe": numbers.Real,
    "hybrid_sim_generator_us_watchdog_pipe": numbers.Real,
    "hybrid_sim_hybrid_us_watchdog_pipe": numbers.Real,
    "hybrid_queries_watchdog_pipe": numbers.Integral,
    "hybrid_ops_watchdog_pipe": numbers.Integral,
    # PR 4: steady-state query periodization (poll-loop bursts)
    "query_periodization_speedup_fig2_timer": numbers.Real,
    "query_periodization_speedup_fig2_poll_burst": numbers.Real,
    "query_periodization_sim_generator_us_fig2_timer": numbers.Real,
    "query_periodization_sim_hybrid_us_fig2_timer": numbers.Real,
    "query_periodization_bulk_queries_fig2_timer": numbers.Integral,
    # PR 5: served DSE sweeps (repro/sweep)
    "sweep_warm_configs_per_sec": numbers.Real,
    "sweep_cold_configs_per_sec": numbers.Real,
    "sweep_service_speedup_vs_loop": numbers.Real,
    "sweep_dedup_ratio": numbers.Real,
    "sweep_cache_hit_rate": numbers.Real,
    # PR 6: fault-tolerant sweep serving (repro/sweep faults + admission)
    "sweep_fault_free_configs_per_sec": numbers.Real,
    "sweep_fault_injected_configs_per_sec": numbers.Real,
    "sweep_fault_recovery_overhead": numbers.Real,
    "sweep_fault_retries": numbers.Integral,
    "sweep_fault_p99_interactive_ms": numbers.Real,
    # PR 7: constrained-random corpus scaling (repro/corpus)
    "corpus_modules_per_sec_generator_100": numbers.Real,
    "corpus_modules_per_sec_generator_300": numbers.Real,
    "corpus_modules_per_sec_generator_1000": numbers.Real,
    "corpus_modules_per_sec_auto_100": numbers.Real,
    "corpus_modules_per_sec_auto_300": numbers.Real,
    "corpus_modules_per_sec_auto_1000": numbers.Real,
    "corpus_sweep_configs_per_sec_300": numbers.Real,
    "corpus_rtl_agree_count": numbers.Integral,
    # PR 8: sparse chain-structured Pallas max-plus lane (backend="jax")
    "maxplus_sparse_us_per_config_1000": numbers.Real,
    "maxplus_sparse_us_per_config_10000": numbers.Real,
    "maxplus_sparse_us_per_config_100000": numbers.Real,
    "maxplus_sparse_vs_numpy_speedup": numbers.Real,
    # PR 9: whole-run cached replay + generalized query periodization.
    # The warm hybrid_replay_speedup_* keys above now measure the cached
    # fast path; the *_cold_* keys pin the uncached profile alongside.
    "hybrid_replay_cold_speedup_fig2_timer": numbers.Real,
    "hybrid_replay_cold_speedup_branch": numbers.Real,
    "hybrid_replay_cold_speedup_multicore": numbers.Real,
    "hybrid_replay_cold_speedup_watchdog_pipe": numbers.Real,
    "query_periodization_speedup_multisite_poll": numbers.Real,
    "query_periodization_speedup_nb_success_stream": numbers.Real,
    "query_periodization_bulk_queries_multisite_poll": numbers.Integral,
    "query_periodization_bulk_queries_nb_success_stream": numbers.Integral,
    # PR 10: structural deltas — edit-and-resimulate (repro/delta)
    "delta_resim_speedup_300": numbers.Real,
    "delta_reuse_fraction_300": numbers.Real,
    "delta_reject_rate": numbers.Real,
    # mode flag, not a measurement: the maxplus_sparse_* numbers come from
    # Pallas interpret mode (XLA on CPU) unless this is False
    "maxplus_sparse_jax_interpret": bool,
}

_DOC_KEY = re.compile(r"^\|\s*`([a-z0-9_]+)`\s*\|", re.MULTILINE)


def _validate(data: dict, origin: str) -> None:
    missing = [k for k in REQUIRED_KEYS if k not in data]
    assert not missing, f"{origin} is missing keys: {missing}"
    bad = [k for k, t in REQUIRED_KEYS.items()
           if not isinstance(data[k], t)
           or (t is not bool and isinstance(data[k], bool))]
    assert not bad, f"{origin} has wrongly-typed keys: {bad}"
    nonpos = [k for k, t in REQUIRED_KEYS.items()
              if t is not bool and not data[k] > 0]
    assert not nonpos, f"{origin} has non-positive values: {nonpos}"


def test_committed_bench_core_schema():
    with open(os.path.join(REPO, "BENCH_core.json")) as f:
        data = json.load(f)
    _validate(data, "BENCH_core.json")


def test_documented_keys_exist_in_committed_file():
    """Every key the dse_guide's schema table documents must be present in
    the committed file (and required above, so benchmarks keep writing it)."""
    with open(os.path.join(REPO, "docs", "dse_guide.md")) as f:
        doc_keys = set(_DOC_KEY.findall(f.read()))
    assert doc_keys, "docs/dse_guide.md schema table not found"
    with open(os.path.join(REPO, "BENCH_core.json")) as f:
        data = json.load(f)
    missing = sorted(doc_keys - set(data))
    assert not missing, f"documented but absent from BENCH_core.json: {missing}"
    undeclared = sorted(doc_keys - set(REQUIRED_KEYS))
    assert not undeclared, (
        f"documented keys not pinned by REQUIRED_KEYS (add them): "
        f"{undeclared}")


@pytest.mark.bench
def test_quick_benchmark_writes_valid_schema(tmp_path):
    """``benchmarks/run.py --quick`` must regenerate every required key."""
    out = tmp_path / "BENCH_core.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick",
         "--out", str(out)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    with open(out) as f:
        data = json.load(f)
    _validate(data, "quick-mode output")
    # the quick refresh must produce the same key set as the committed file
    with open(os.path.join(REPO, "BENCH_core.json")) as f:
        committed = json.load(f)
    assert set(data) == set(committed)
