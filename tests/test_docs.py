"""Docs cannot rot silently (ISSUE 2 satellite).

Two contracts:

  1. every script in ``examples/`` runs to completion (reduced args where
     the example is a long-running driver);
  2. every repo path and every fully-qualified ``repro...`` symbol named
     in ``docs/*.md`` / ``README.md`` exists — docs referring to renamed
     or deleted code fail the tier-1 suite.
"""
import importlib
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# ---------------------------------------------------------------- examples
# Every file in examples/ must be registered here (enforced below) with
# the arguments that make it a CI-sized run.
EXAMPLE_ARGS = {
    "quickstart.py": [],
    "fifo_sizing_dse.py": [],
    "pipeline_perfsim.py": [],
    "train_smollm.py": ["--steps", "2"],
}


def test_every_example_is_registered():
    on_disk = sorted(f for f in os.listdir(os.path.join(REPO, "examples"))
                     if f.endswith(".py"))
    assert on_disk == sorted(EXAMPLE_ARGS), (
        "examples/ and EXAMPLE_ARGS disagree — register new examples here "
        "so they are executed by the docs suite")


@pytest.mark.parametrize("name", sorted(EXAMPLE_ARGS))
def test_example_runs(name, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", name)]
        + EXAMPLE_ARGS[name],
        cwd=tmp_path,                      # artifacts (checkpoints/) go here
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"examples/{name} failed:\n--- stdout ---\n{proc.stdout[-2000:]}\n"
        f"--- stderr ---\n{proc.stderr[-2000:]}")


# ------------------------------------------------------------- doc symbols
DOC_FILES = ["README.md", "docs/architecture.md", "docs/api.md",
             "docs/dse_guide.md", "docs/sweep_guide.md"]

_TOKEN = re.compile(r"`([^`\n]+)`")
_DOTTED = re.compile(r"^repro(\.[A-Za-z_]\w*)+$")


def _tokens(doc):
    with open(os.path.join(REPO, doc)) as f:
        return _TOKEN.findall(f.read())


def test_doc_files_exist():
    for doc in DOC_FILES:
        assert os.path.exists(os.path.join(REPO, doc)), doc


@pytest.mark.parametrize("doc", DOC_FILES)
def test_doc_paths_exist(doc):
    """Backticked repo paths (src/..., docs/..., *.py, *.md, *.json) must
    exist on disk — also tried relative to src/repro for `core/...` style
    references."""
    missing = []
    for tok in _tokens(doc):
        if ("/" not in tok or any(c in tok for c in " *(,=<>{")
                or tok.startswith("http")):
            continue
        rel = tok.rstrip("/")
        if not (os.path.exists(os.path.join(REPO, rel))
                or os.path.exists(os.path.join(SRC, "repro", rel))):
            missing.append(tok)
    assert not missing, f"{doc} names nonexistent paths: {missing}"


@pytest.mark.parametrize("doc", DOC_FILES)
def test_doc_symbols_resolve(doc):
    """Backticked fully-qualified names (`repro.x.y[.Z[.attr]](...)`) must
    import/resolve — the call-signature tail is ignored."""
    sys.path.insert(0, SRC)
    try:
        bad = []
        for tok in _tokens(doc):
            name = tok.split("(")[0].strip()
            if not _DOTTED.match(name):
                continue
            parts = name.split(".")
            obj, rest = None, parts
            for i in range(len(parts), 0, -1):
                try:
                    obj = importlib.import_module(".".join(parts[:i]))
                    rest = parts[i:]
                    break
                except ImportError:
                    continue
            if obj is None:
                bad.append(tok)
                continue
            try:
                for attr in rest:
                    obj = getattr(obj, attr)
            except AttributeError:
                bad.append(tok)
        assert not bad, f"{doc} names unresolvable symbols: {bad}"
    finally:
        sys.path.remove(SRC)


def test_api_doc_covers_public_exports():
    """Every name in repro.core.__all__, repro.corpus.__all__ and
    repro.delta.__all__ must be mentioned in docs/api.md — new public API
    cannot ship undocumented."""
    sys.path.insert(0, SRC)
    try:
        import repro.core as core
        import repro.corpus as corpus
        import repro.delta as delta
        with open(os.path.join(REPO, "docs", "api.md")) as f:
            text = f.read()
        missing = [n for n in (list(core.__all__) + list(corpus.__all__)
                               + list(delta.__all__))
                   if n not in text]
        assert not missing, f"docs/api.md does not mention: {missing}"
    finally:
        sys.path.remove(SRC)
