"""Expert-parallel MoE (shard_map + all-to-all) vs the dense-masked oracle.

These tests need a multi-device host; they run themselves in a subprocess
with XLA_FLAGS forcing 8 host devices (the flag must precede jax init, so
it cannot be set inside the main pytest process).
"""
import os
import subprocess
import sys
import textwrap

import pytest


def _run_subprocess(body: str):
    code = "import os\n" \
           "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n" \
           + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_ep_matches_dense_high_capacity():
    _run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models.moe import init_moe, moe_dense, moe_ep
    cfg = get_arch("qwen3-moe-30b-a3b").smoke()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p = init_moe(jax.random.PRNGKey(0), cfg, expert_shards=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    dense = moe_dense(p, x, cfg)
    ep = moe_ep(p, x, cfg, mesh, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ep),
                               rtol=3e-2, atol=3e-2)
    print("EP==dense OK")
    """)


def test_ep_capacity_drops_bounded():
    _run_subprocess("""
    import jax, jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models.moe import init_moe, moe_ep
    cfg = get_arch("qwen3-moe-30b-a3b").smoke()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p = init_moe(jax.random.PRNGKey(0), cfg, expert_shards=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    ep = moe_ep(p, x, cfg, mesh, capacity_factor=1.0)
    assert bool(jnp.isfinite(ep).all())
    print("EP capacity OK")
    """)


def test_int8_kv_decode_close_to_bf16():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import api

    cfg = get_arch("minicpm-2b").smoke()
    cfgq = cfg.replace(kv_quant=True)
    p = api.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                              cfg.vocab_size)
    c = api.init_cache(cfg, 2, 16)
    cq = api.init_cache(cfgq, 2, 16)
    for t in range(6):
        lg, c = api.decode_step(p, toks[:, t:t + 1], c, cfg)
        lgq, cq = api.decode_step(p, toks[:, t:t + 1], cq, cfgq)
    assert float(jnp.abs(lg - lgq).max()) < 0.15
