"""Structural deltas & edit sessions (``repro/delta``, ISSUE 10).

The contract: an edit served through the delta subsystem — fingerprint
diff, per-module trace patch, served :class:`EditSession` — must be
*bit-identical* to simulating the edited design from scratch, or it must
reject to a cold rebuild (which is trivially bit-identical).  Stale reuse
is never an acceptable failure mode; slow reuse is.

Tier-1 runs every delta class at small scale plus the cache/scheduler
integration; the 300-module differential sweep hides behind ``-m delta``.
"""
import time

import numpy as np
import pytest

from repro.core import simulate
from repro.core.program import Delay, Emit, Program, Read, ReadNB, Write
from repro.core.trace import program_fingerprint
from repro.corpus import (EDIT_KINDS, PATCHABLE_KINDS, edit_pairs,
                          result_record)
from repro.delta import (BODY_EDITED, RENAMED, RETYPED, UNCHANGED,
                         EditSession, apply_patch, cold_build, diff,
                         fingerprint_design, snapshot)
from repro.sweep import GraphCache, SweepService


def _manual_service(**kw):
    kw.setdefault("autostart", False)
    return SweepService(**kw)


@pytest.fixture(scope="module")
def pairs():
    """One probe-selected base design, all seven edit classes on it."""
    return {p.kind: p for p in edit_pairs(3, scale=28)}


# ---------------------------------------------------------- fingerprint/diff
def test_fingerprint_key_matches_program_fingerprint(pairs):
    prog = pairs["delay"].base()
    fps = fingerprint_design(prog)
    assert fps.key == program_fingerprint(prog)
    assert fps.module_names == tuple(m.name for m in prog.modules)


def test_diff_identical_builders(pairs):
    p = pairs["delay"]
    d = diff(fingerprint_design(p.base()), fingerprint_design(p.base()))
    assert d.identical and d.patchable and not d.edited
    assert all(lbl == UNCHANGED for lbl in d.modules.values())


def test_diff_classifies_body_edit(pairs):
    p = pairs["delay"]
    d = diff(fingerprint_design(p.base()), fingerprint_design(p.edited()))
    assert d.patchable and not d.identical
    assert BODY_EDITED in d.modules.values()
    # a pure timing edit touches exactly the edited module
    assert sum(1 for v in d.modules.values() if v != UNCHANGED) == 1


def test_diff_classifies_retype_and_rename(pairs):
    base = fingerprint_design(pairs["retype"].base())
    d = diff(base, fingerprint_design(pairs["retype"].edited()))
    assert d.patchable and RETYPED in [lbl for _, lbl in d.fifos]
    assert all(lbl == UNCHANGED for lbl in d.modules.values())
    d = diff(base, fingerprint_design(pairs["rename"].edited()))
    assert not d.patchable and RENAMED in [lbl for _, lbl in d.fifos]
    assert "renam" in d.reason


def test_diff_rejects_topology_changes(pairs):
    for kind in ("interface", "added", "removed"):
        p = pairs[kind]
        d = diff(fingerprint_design(p.base()), fingerprint_design(p.edited()))
        assert not d.patchable, kind
        assert d.reason, kind


# ------------------------------------------------------- differential patch
@pytest.mark.parametrize("kind", EDIT_KINDS)
def test_patch_bit_identical_or_rejects(pairs, kind):
    """Every edit class: a patched result equals the cold run bit-for-bit
    (cycles, outputs, FIFO digests, stats); a reject falls back to cold."""
    p = pairs[kind]
    _, state = snapshot(p.base())
    cold, _ = snapshot(p.edited())
    out = apply_patch(state, p.edited())
    if p.expect == "patched":
        assert out.ok, (kind, out.reason)
        assert result_record(out.result) == result_record(cold)
        assert out.reused_modules >= out.total_modules - 1
    else:
        assert not out.ok and out.reason, kind
    # the served answer is bit-identical either way
    served = out.result if out.ok else cold
    assert result_record(served) == result_record(cold)


def test_patch_chains_from_patched_state(pairs):
    """delta -> retype applied on top of a patched snapshot: each hop
    verifies against its own cold run."""
    d, r = pairs["delay"], pairs["retype"]
    _, state = snapshot(d.base())
    out1 = apply_patch(state, d.edited())
    assert out1.ok
    # retype pair shares the same base design, so its edited rows apply
    # cleanly on top of the delay edit via a fresh builder combination
    out2 = apply_patch(out1.state, d.base())     # edit it *back*
    assert out2.ok, out2.reason
    cold, _ = snapshot(d.base())
    assert result_record(out2.result) == result_record(cold)


def test_value_edit_reject_reason_names_the_stream(pairs):
    p = pairs["value"]
    _, state = snapshot(p.base())
    out = apply_patch(state, p.edited())
    assert not out.ok and "write stream" in out.reason


# --------------------------------------------------------- delta-aware cache
def test_cache_get_or_patch_tiers(pairs):
    p = pairs["delay"]
    cache = GraphCache(capacity=4)
    fps0 = fingerprint_design(p.base())
    look0 = cache.get_or_patch(p.base(), fps0, None)
    assert look0.mode == "cold" and look0.state is not None
    # tier 2: patch from the held state
    fps1 = fingerprint_design(p.edited())
    look1 = cache.get_or_patch(p.edited(), fps1, look0.state)
    assert look1.mode == "patched"
    assert look1.entry.key == fps1.key != fps0.key
    # tier 1: the patched entry now answers the exact key
    look2 = cache.get_or_patch(p.edited(), fps1, None)
    assert look2.mode == "exact" and look2.entry is look1.entry
    st = cache.stats()
    assert st["delta_hits"] == 1 and st["delta_rejects"] == 0


def test_cache_reject_falls_back_cold(pairs):
    p = pairs["value"]
    cache = GraphCache(capacity=4)
    look0 = cache.get_or_patch(p.base(), fingerprint_design(p.base()), None)
    look1 = cache.get_or_patch(p.edited(), fingerprint_design(p.edited()),
                               look0.state)
    assert look1.mode == "cold" and look1.reason
    assert cache.stats()["delta_rejects"] == 1
    ref = simulate(p.edited())
    assert result_record(look1.entry.result) == result_record(ref)


# ------------------------------------------------------------- edit sessions
def _depth_block(prog, rows=4):
    d0 = np.asarray(prog.depths(), dtype=np.int64)
    return np.stack([np.maximum(d0 + k, 1) for k in range(rows)])


def test_edit_session_serves_patched_design(pairs):
    p = pairs["delay"]
    with _manual_service(block=4) as svc:
        sess = svc.edit_session(p.base())
        D = _depth_block(p.base())
        sess.sweep(D)                       # warm the base entry
        out = sess.update(p.edited())
        assert out.mode == "patched" and out.reuse_fraction >= 0.9
        served = sess.sweep(D)
    with _manual_service(block=4) as svc2:
        ref = svc2.sweep(p.edited(), D)
    assert (served.status == ref.status).all()
    assert (served.cycles == ref.cycles).all()
    for k in range(len(D)):
        if ref.results[k] is not None:
            assert served.results[k].outputs == ref.results[k].outputs


def test_edit_session_modes_and_counts(pairs):
    pd, pv = pairs["delay"], pairs["value"]
    with _manual_service(block=4) as svc:
        sess = svc.edit_session(pd.base())
        assert sess.update(pd.base()).mode == "unchanged"
        assert sess.update(pd.edited()).mode == "patched"
        out = sess.update(pv.edited())      # value edit vs delay-edited state
        assert out.mode == "cold" and out.reason
        # back to a design the cache already holds: exact-key reuse
        assert sess.update(pd.edited()).mode == "exact"
        st = sess.stats()
        assert st["unchanged"] == 1 and st["patched"] == 1
        assert st["cold"] == 1 and st["rejected"] == 1 and st["exact"] == 1
        cst = svc.stats()["cache"]
        assert cst["delta_hits"] >= 1 and cst["delta_rejects"] >= 1


def test_edit_session_dynamic_design_goes_cold():
    """NB polling designs have no recorded snapshot: every edit rebuilds
    cold, but exact-key reuse still works and nothing crashes."""
    def build(d=10):
        prog = Program("poll_edit", declared_type="B")
        f = prog.fifo("f", 2)

        @prog.module("p")
        def p():
            yield Delay(d)
            yield Write(f, 42)

        @prog.module("c")
        def c():
            polls = 0
            while True:
                ok, _v = yield ReadNB(f)
                polls += 1
                if ok:
                    break
            yield Emit("polls", polls)
        return prog

    with _manual_service(block=4) as svc:
        sess = svc.edit_session(build())
        assert sess.state is None
        out = sess.update(build(d=20))
        assert out.mode == "cold"
        ref = simulate(build(d=20))
        assert result_record(sess.entry.result) == result_record(ref)
        assert sess.update(build(d=10)).mode == "exact"


# ---------------------------------------------- scheduler cross-block memo
def test_scheduler_memoizes_repeat_configs(pairs):
    p = pairs["delay"]
    D = _depth_block(p.base(), rows=3)
    with _manual_service(block=2) as svc:
        a = svc.sweep(p.base(), D)
        assert svc.stats()["scheduler"]["memo_hits"] == 0
        b = svc.sweep(p.base(), D)
        assert svc.stats()["scheduler"]["memo_hits"] == len(D)
        assert (a.status == b.status).all() and (a.cycles == b.cycles).all()
        assert svc.stats()["scheduler"]["memo_size"] >= len(D)


def test_scheduler_memo_disabled(pairs):
    p = pairs["delay"]
    D = _depth_block(p.base(), rows=3)
    with _manual_service(block=2, memo_capacity=0) as svc:
        svc.sweep(p.base(), D)
        svc.sweep(p.base(), D)
        assert svc.stats()["scheduler"]["memo_hits"] == 0


def test_scheduler_memo_is_per_design_content(pairs):
    """Same depth rows against base and edited designs must NOT share
    memo entries — keys are (design key, depth row)."""
    p = pairs["delay"]
    D = _depth_block(p.base(), rows=2)
    with _manual_service(block=2) as svc:
        a = svc.sweep(p.base(), D)
        b = svc.sweep(p.edited(), D)
        assert svc.stats()["scheduler"]["memo_hits"] == 0
        ra = simulate(p.base(), depths=list(map(int, D[0])))
        rb = simulate(p.edited(), depths=list(map(int, D[0])))
        assert a.cycles[0] == ra.cycles and b.cycles[0] == rb.cycles


# ------------------------------------------------------------ full-run spill
def test_cache_spills_full_run_for_dynamic_designs():
    def build():
        prog = Program("poll_spill", declared_type="B")
        f = prog.fifo("f", 2)

        @prog.module("p")
        def p():
            yield Delay(10)
            yield Write(f, 7)

        @prog.module("c")
        def c():
            polls = 0
            while True:
                ok, _v = yield ReadNB(f)
                polls += 1
                if ok:
                    break
            yield Emit("polls", polls)
        return prog

    cache = GraphCache(capacity=2)
    entry = cache.get_or_build(build())
    assert entry.result.engine == "omnisim-hybrid"
    assert entry.full_run is not None
    assert cache.stats()["full_runs"] == 1
    # a hit reinstalls the spilled run into the shared HybridCache
    cache.hybrid._full.clear()
    assert cache.lookup(entry.key) is entry
    assert cache.hybrid.peek_full(entry.key) is entry.full_run


def test_traced_designs_have_no_full_run(pairs):
    cache = GraphCache(capacity=2)
    entry = cache.get_or_build(pairs["delay"].base())
    assert entry.full_run is None


# ------------------------------------------------------------- big tier
@pytest.mark.delta
@pytest.mark.parametrize("kind", EDIT_KINDS)
def test_delta_differential_300(kind):
    """300-module designs: every edit class, served answer bit-identical
    to cold; patchable classes must reuse >= 90% of modules."""
    p = {q.kind: q for q in edit_pairs(11, scale=300)}[kind]
    _, state = snapshot(p.base())
    t0 = time.perf_counter()
    cold, _ = snapshot(p.edited())
    t_cold = time.perf_counter() - t0
    out = apply_patch(state, p.edited())
    if kind in PATCHABLE_KINDS:
        assert out.ok, out.reason
        assert out.reuse_fraction >= 0.9
        assert out.elapsed_s < max(t_cold, 1e-3) * 5
    else:
        assert not out.ok
    served = out.result if out.ok else cold
    assert result_record(served) == result_record(cold)
