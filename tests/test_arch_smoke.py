"""Per-architecture smoke tests: reduced configs, one forward + train-grad +
decode step on CPU; asserts shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import api
from repro.models.frontends import synthetic_frontend

BATCH, SEQ = 2, 32


def _inputs(cfg, batch=BATCH, seq=SEQ, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    targets = jnp.roll(tokens, -1, axis=1)
    frontend = synthetic_frontend(cfg, batch)
    return tokens, targets, frontend


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finiteness(name):
    cfg = get_arch(name).smoke()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    tokens, _, frontend = _inputs(cfg)
    logits = api.forward(params, tokens, cfg, frontend)
    S_out = SEQ + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (BATCH, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_grad_finite(name):
    cfg = get_arch(name).smoke()
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    tokens, targets, frontend = _inputs(cfg)

    def loss(p):
        return api.loss_fn(p, tokens, targets, cfg, frontend)

    val, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(val)), f"{name}: non-finite loss {val}"
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert bool(jnp.isfinite(g).all()), f"{name}: non-finite grad"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name):
    cfg = get_arch(name).smoke()
    if not cfg.supports_decode:
        pytest.skip("no decode step for this arch")
    params = api.init_params(jax.random.PRNGKey(2), cfg)
    cache = api.init_cache(cfg, BATCH, max_len=64)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    logits, cache = api.decode_step(params, tok, cache, cfg)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    logits2, cache = api.decode_step(params, tok, cache, cfg)
    assert bool(jnp.isfinite(logits2).all())
    assert int(cache["pos"][0]) == 2


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_arch("smollm-135m").smoke()
    params = api.init_params(jax.random.PRNGKey(3), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                                cfg.vocab_size)
    full = api.forward(params, tokens, cfg)
    cache = api.init_cache(cfg, 1, max_len=16)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, tokens[:, t:t + 1], cache, cfg)
        outs.append(lg[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_sliding_window():
    cfg = get_arch("gemma2-2b").smoke().replace(sliding_window=4,
                                                local_global_pattern=True)
    params = api.init_params(jax.random.PRNGKey(5), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (1, 12), 0,
                                cfg.vocab_size)
    full = api.forward(params, tokens, cfg)
    cache = api.init_cache(cfg, 1, max_len=16)
    outs = []
    for t in range(12):
        lg, cache = api.decode_step(params, tokens[:, t:t + 1], cache, cfg)
        outs.append(lg[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    """Chunked SSD scan (forward) vs step recurrence (decode) consistency."""
    cfg = get_arch("xlstm-1.3b").smoke()
    params = api.init_params(jax.random.PRNGKey(7), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(8), (1, 8), 0,
                                cfg.vocab_size)
    full = api.forward(params, tokens, cfg)
    cache = api.init_cache(cfg, 1, max_len=16)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, tokens[:, t:t + 1], cache, cfg)
        outs.append(lg[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=3e-2, atol=3e-2)


def test_decode_matches_forward_hybrid():
    cfg = get_arch("hymba-1.5b").smoke().replace(sliding_window=0)
    params = api.init_params(jax.random.PRNGKey(9), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(10), (1, 8), 0,
                                cfg.vocab_size)
    full = api.forward(params, tokens, cfg)
    cache = api.init_cache(cfg, 1, max_len=16)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, tokens[:, t:t + 1], cache, cfg)
        outs.append(lg[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=3e-2, atol=3e-2)


def test_moe_routing_actually_sparse():
    """Only top-k experts may contribute: zeroing unused experts' weights
    must not change the output."""
    cfg = get_arch("qwen3-moe-30b-a3b").smoke()
    from repro.models.moe import _route, init_moe, moe_dense
    key = jax.random.PRNGKey(11)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(12), (1, 4, cfg.d_model))
    w, idx = _route(p, x, cfg.moe)
    used = np.unique(np.asarray(idx))
    out = moe_dense(p, x, cfg)
    p2 = dict(p)
    E = p["router"].shape[-1]
    mask = jnp.zeros((E,), bool).at[jnp.asarray(used)].set(True)
    for k in ("w_gate", "w_up", "w_down"):
        p2[k] = jnp.where(mask[:, None, None], p[k], 0.0)
    out2 = moe_dense(p2, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_gemma2_softcap_applied():
    cfg = get_arch("gemma2-2b").smoke()
    params = api.init_params(jax.random.PRNGKey(13), cfg)
    tokens, _, _ = _inputs(cfg)
    logits = api.forward(params, tokens, cfg)
    assert float(jnp.abs(logits).max()) <= cfg.logit_softcap + 1e-3
