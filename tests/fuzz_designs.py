"""Thin re-export: the fuzz builders now live in :mod:`repro.corpus.builders`.

The seeded macro interpreter and case builders were promoted to library
code so the design-corpus generator (``repro.corpus``) can compose them
into 100-1000-module topologies; this shim keeps the historical import
path (``from fuzz_designs import build_case``) working unchanged for
``tests/test_fuzz.py`` and any out-of-tree harnesses.
"""
from repro.corpus.builders import (MOD, _POLL_PATTERNS, _interp,  # noqa: F401
                                   build_case, build_poll_case)
