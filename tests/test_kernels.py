"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Each kernel is swept over shapes/dtypes (hypothesis + parametrize) and
asserted allclose against its ref.py.  interpret=True executes the kernel
body in Python on CPU; the BlockSpecs/grids are identical to the TPU build.
"""
import functools

import numpy as np
import pytest

jax = pytest.importorskip("jax")
pytest.importorskip("jax.experimental.pallas")
import jax.numpy as jnp

# hypothesis drives only the property tests below; the plain Pallas
# regression tests must keep running where it is not installed
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):          # stand-ins so decorators still apply
        return lambda fn: pytest.mark.skip(reason="hypothesis missing")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class st:                      # noqa: N801 — mirrors hypothesis alias
        integers = sampled_from = staticmethod(lambda *a, **k: None)

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.maxplus.kernel import BLK, NEG, maxplus_sweep
from repro.kernels.maxplus.ops import finalize_times, longest_path
from repro.kernels.maxplus.ref import longest_path_ref, maxplus_sweep_ref
from repro.kernels.mlstm_chunk.ops import mlstm_chunk
from repro.kernels.mlstm_chunk.ref import mlstm_ref


# ------------------------------------------------------------------ maxplus
def _random_dag_dense(rng, n_real, npad):
    a = np.full((npad, npad), int(NEG), dtype=np.int64)
    base = np.full((npad,), int(NEG), dtype=np.int64)
    base[:n_real] = rng.integers(0, 4, size=n_real)
    for i in range(1, n_real):
        for p in rng.choice(i, size=min(i, int(rng.integers(0, 3))),
                            replace=False):
            a[i, p] = int(rng.integers(0, 8))
    return (jnp.asarray(a, jnp.int32), jnp.asarray(base, jnp.int32))


@pytest.mark.parametrize("n_real", [5, 60, 128, 250])
def test_maxplus_kernel_matches_ref(n_real):
    rng = np.random.default_rng(n_real)
    npad = ((n_real + BLK - 1) // BLK) * BLK
    a, base = _random_dag_dense(rng, n_real, npad)
    t_k = longest_path(a, base, use_pallas=True, interpret=True)
    t_r = longest_path_ref(a, base, iters=npad)
    np.testing.assert_array_equal(np.asarray(t_k), np.asarray(t_r))


@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(st.integers(2, 100), st.integers(0, 2**31 - 1))
def test_maxplus_sweep_property(n_real, seed):
    rng = np.random.default_rng(seed)
    npad = ((n_real + BLK - 1) // BLK) * BLK
    a, base = _random_dag_dense(rng, n_real, npad)
    t = base
    s_k = maxplus_sweep(a, t, base, interpret=True)
    s_r = maxplus_sweep_ref(a, t, base)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))


def test_maxplus_finalizes_simulation_graph():
    """End-to-end: kernel longest path == the engine's eager node times."""
    from repro.core import simulate
    from repro.designs.typea import producer_consumer
    res = simulate(producer_consumer(n=40, depth=2))
    times = finalize_times(res.graph.graph, use_pallas=True, interpret=True)
    eager = res.graph.graph.times()
    np.testing.assert_array_equal(np.asarray(times), eager)


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (1, 128, 2, 2, 64),
    (2, 256, 4, 2, 64),
    (1, 256, 8, 2, 128),
    (2, 128, 3, 1, 64),        # odd head count (GQA 3:1)
])
def test_flash_attention_matches_ref(B, S, H, Hkv, hd, dtype):
    keys = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(keys[0], (B, S, H, hd), dtype)
    k = jax.random.normal(keys[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(keys[2], (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, interpret=True)
    G = H // Hkv
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    ref = attention_ref(qb, kb, vb, group_size=G)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [0, 64, 200])
@pytest.mark.parametrize("softcap", [0.0, 50.0])
def test_flash_attention_window_softcap(window, softcap):
    B, S, H, Hkv, hd = 1, 256, 2, 1, 64
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, Hkv, hd))
    v = jax.random.normal(keys[2], (B, S, Hkv, hd))
    out = flash_attention(q, k, v, window=window, softcap=softcap,
                          interpret=True)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    ref = attention_ref(qb, kb, vb, window=window, softcap=softcap,
                        group_size=2)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.property
@settings(max_examples=10, deadline=None)
@given(st.sampled_from([128, 256]), st.sampled_from([1, 2, 4]),
       st.sampled_from([64, 128]), st.integers(0, 2**31 - 1))
def test_flash_attention_property(S, G, hd, seed):
    B, Hkv = 1, 2
    H = Hkv * G
    keys = jax.random.split(jax.random.PRNGKey(seed % (2**31 - 1)), 3)
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, Hkv, hd))
    v = jax.random.normal(keys[2], (B, S, Hkv, hd))
    out = flash_attention(q, k, v, interpret=True)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    ref = attention_ref(qb, kb, vb, group_size=G)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_matches_model_sdpa():
    """Kernel vs the model's XLA attention path (the dry-run path)."""
    from repro.configs import get_arch
    from repro.models.attention import _project_qkv, _sdpa
    from repro.models.common import causal_mask
    cfg = get_arch("smollm-135m").smoke()
    B, S = 1, 128
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    hd = cfg.resolved_head_dim
    q = jax.random.normal(keys[0], (B, S, cfg.num_heads, hd))
    k = jax.random.normal(keys[1], (B, S, cfg.num_kv_heads, hd))
    v = jax.random.normal(keys[2], (B, S, cfg.num_kv_heads, hd))
    pos = jnp.arange(S)[None]
    mask = causal_mask(pos, pos)
    xla_out = _sdpa(q, k, v, mask, cfg)
    pl_out = flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(xla_out),
                               np.asarray(pl_out.reshape(B, S, -1)),
                               rtol=3e-5, atol=3e-5)


# -------------------------------------------------------------- mlstm chunk
@pytest.mark.parametrize("S,chunk", [(128, 32), (128, 128), (256, 64)])
@pytest.mark.parametrize("P,Pv", [(32, 32), (64, 65)])
def test_mlstm_chunk_matches_ref(S, chunk, P, Pv):
    B, H = 2, 3
    keys = jax.random.split(jax.random.PRNGKey(S + P), 5)
    q = jax.random.normal(keys[0], (B, S, H, P)) * 0.3
    k = jax.random.normal(keys[1], (B, S, H, P)) * 0.3
    v = jax.random.normal(keys[2], (B, S, H, Pv))
    ig = jax.nn.sigmoid(jax.random.normal(keys[3], (B, S, H)))
    la = jax.nn.log_sigmoid(jax.random.normal(keys[4], (B, S, H)) + 1.0)
    out = mlstm_chunk(q, k, v, ig, la, chunk=chunk, interpret=True)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, S, Pv)
    igb = ig.transpose(0, 2, 1).reshape(B * H, S)
    lab = la.transpose(0, 2, 1).reshape(B * H, S)
    ref = mlstm_ref(qb, kb, vb, igb, lab)
    ref = ref.reshape(B, H, S, Pv).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_chunk_matches_model_scan():
    """Kernel vs the model's _ssd_scan_perhead (the XLA dry-run path)."""
    from repro.models.xlstm import _ssd_scan_perhead
    B, S, H, P = 1, 128, 2, 32
    keys = jax.random.split(jax.random.PRNGKey(11), 5)
    q = jax.random.normal(keys[0], (B, S, H, P)) * 0.3
    k = jax.random.normal(keys[1], (B, S, H, P)) * 0.3
    v = jax.random.normal(keys[2], (B, S, H, P + 1))
    ig = jax.nn.sigmoid(jax.random.normal(keys[3], (B, S, H)))
    la = jax.nn.log_sigmoid(jax.random.normal(keys[4], (B, S, H)) + 1.0)
    scan_out = _ssd_scan_perhead(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        ig, la, chunk=32)
    pl_out = mlstm_chunk(q, k, v, ig, la, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(scan_out), np.asarray(pl_out),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- sparse maxplus
from repro.kernels.maxplus.sparse import (segmented_cummax,
                                          segmented_cummax_ref)


def _random_segments(rng, npad):
    seg = np.zeros(npad, np.int32)
    lo = 0
    while lo < npad:
        ln = int(rng.integers(1, 17))
        seg[lo:min(lo + ln, npad)] = lo
        lo += ln
    return seg


def _segcummax_oracle(x, seg):
    want = x.copy()
    for j in range(1, x.shape[1]):
        if seg[j] <= j - 1:            # previous column in the same segment
            want[:, j] = np.maximum(want[:, j], want[:, j - 1])
    return want


@pytest.mark.parametrize("K,npad", [(8, 128), (32, 256), (64, 128)])
def test_segmented_cummax_matches_oracle(K, npad):
    """Pallas segmented cummax (and its jnp ref) vs a sequential oracle."""
    rng = np.random.default_rng(K + npad)
    seg = _random_segments(rng, npad)
    x = rng.integers(-50, 50, size=(K, npad)).astype(np.int32)
    want = _segcummax_oracle(x, seg)
    got_pl = np.asarray(segmented_cummax(jnp.asarray(x), jnp.asarray(seg),
                                         interpret=True))
    got_ref = np.asarray(segmented_cummax_ref(jnp.asarray(x),
                                              jnp.asarray(seg)))
    assert (got_pl == want).all()
    assert (got_ref == want).all()


def test_segmented_cummax_max_seg_cap():
    """Capping the doubling scan at the longest segment must not change
    the result (segments here are <= 16 columns)."""
    rng = np.random.default_rng(5)
    seg = _random_segments(rng, 256)
    x = rng.integers(-50, 50, size=(16, 256)).astype(np.int32)
    want = _segcummax_oracle(x, seg)
    for max_seg in (16, 17, None):
        got = np.asarray(segmented_cummax(jnp.asarray(x), jnp.asarray(seg),
                                          max_seg=max_seg, interpret=True))
        assert (got == want).all(), max_seg


def test_solve_chains_matches_numpy_seeded_solver():
    """End-to-end sparse solve over exported flat arrays vs the numpy
    Gauss-Seidel production solver, WAR edges active."""
    from repro.core import simulate
    from repro.core.dse import (_batch_arrays, _solve_block_numpy,
                                _solve_sparse_jax)
    from repro.core.incremental import compile_graph
    from repro.designs.typea import skynet_like

    base = simulate(skynet_like(items=16, depth=4))
    g = compile_graph(base.graph)
    ba = _batch_arrays(g)
    rng = np.random.default_rng(2)
    Db = rng.integers(1, 9, size=(8, len(base.depths))).astype(np.int64)
    t_np, conv_np, _ = _solve_block_numpy(ba, Db)
    t_jx, conv_jx, _ = _solve_sparse_jax(g, ba, Db)
    assert (conv_np == conv_jx).all()
    # converged configs: full (n, K) node-time agreement, not just cycles
    cols = np.flatnonzero(conv_np)
    assert (np.asarray(t_np)[:, cols] == t_jx[:, cols]).all()
