"""Batched incremental re-simulation (core/dse.py).

Exactness contract: ``resimulate_batch(result, D)[k]`` must agree
config-for-config with ``resimulate(result, D[k])`` — same reuse verdict —
and, for every config, with a from-scratch ``simulate()`` under those
depths (cycle counts and outputs), whether the config was reused or fell
back (deadlock / WAR cycle / constraint flip).
"""
import numpy as np
import pytest

from repro.core import resimulate, resimulate_batch, simulate
from repro.core.program import Emit, Program, Read, Write
from repro.designs.paper import fig4_ex4a, fig4_ex5
from repro.designs.typea import producer_consumer, skynet_like


def _assert_batch_exact(out, builder, D, base):
    """Every config: verdict matches looped resimulate, numbers match a
    from-scratch simulation."""
    for k in range(len(D)):
        depths = tuple(int(d) for d in D[k])
        inc = resimulate(base, depths)
        full = simulate(builder(), depths=depths)
        assert bool(out.ok[k]) == inc.ok, \
            (k, depths, out.reasons[k], inc.reason)
        assert out.cycles[k] == full.cycles, (k, depths)
        assert out.results[k].outputs == full.outputs, (k, depths)
        assert out.results[k].deadlock == full.deadlock, (k, depths)


# ------------------------------------------------------------------- Type A
def test_batch_matches_loop_and_full_typea():
    """Deep blocking-only pipeline; depths from starving (1) to slack."""
    builder = lambda: skynet_like(items=48, depth=6)
    base = simulate(builder())
    rng = np.random.default_rng(7)
    D = rng.integers(1, 13, size=(24, len(base.depths)))
    out = resimulate_batch(base, D)
    _assert_batch_exact(out, builder, D, base)
    assert out.n_reused > 0          # slack configs must actually reuse


def test_batch_single_and_shapes():
    base = simulate(producer_consumer(n=32, depth=2))
    out = resimulate_batch(base, [8])            # 1-D = one config
    full = simulate(producer_consumer(n=32, depth=8))
    assert out.cycles[0] == full.cycles and out.ok.shape == (1,)
    with pytest.raises(ValueError):
        resimulate_batch(base, np.ones((3, 5), dtype=int))


# ------------------------------------------------------------------- Type C
def test_batch_typec_constraint_flips():
    """fig4_ex5: (2,100) reuses, (100,2) flips constraints mid-batch —
    the batch must mix reuse and fallback correctly (paper Table 6)."""
    base = simulate(fig4_ex5())
    D = np.array([(2, 100), (100, 2), (2, 2), (1, 1), (64, 64)])
    out = resimulate_batch(base, D)
    _assert_batch_exact(out, fig4_ex5, D, simulate(fig4_ex5()))
    assert bool(out.ok[0]) and not bool(out.ok[1])
    assert "constraint" in out.reasons[1]
    # the two ends genuinely diverge functionally
    assert out.results[0].outputs != out.results[1].outputs


def test_batch_typec_nb_drop_design():
    """fig4_ex4a (silent-drop WriteNB): depth changes alter the dropped
    set, so most shrinks must be caught by the constraint re-check."""
    base = simulate(fig4_ex4a(n=96))
    D = np.array([[1], [2], [3], [8], [96]])
    out = resimulate_batch(base, D)
    _assert_batch_exact(out, lambda: fig4_ex4a(n=96), D, simulate(fig4_ex4a(n=96)))


def test_batch_detects_new_deadlock():
    """A config that starves a committed blocking write must be masked
    structurally and fall back to a full (deadlocking) simulation."""
    def leftover():
        prog = Program("leftover", declared_type="A")
        d = prog.fifo("d", 8)

        @prog.module("p")
        def p():
            for i in range(8):
                yield Write(d, i)

        @prog.module("c")
        def c():
            tot = 0
            for _ in range(4):
                tot += (yield Read(d))
            yield Emit("sum", tot)

        return prog

    base = simulate(leftover())
    assert not base.deadlock
    D = np.array([[8], [4], [3], [1]])
    out = resimulate_batch(base, D)
    _assert_batch_exact(out, leftover, D, simulate(leftover()))
    assert bool(out.ok[0]) and bool(out.ok[1])
    assert not bool(out.ok[2]) and not bool(out.ok[3])
    assert "deadlock" in out.reasons[2]
    assert out.results[2].deadlock        # fallback reproduces the deadlock


def test_batch_detects_war_cycle():
    """Shrinking BOTH channels of a burst ping-pong inverts the recorded
    event order (a genuine WAR cycle across two FIFOs): the batch must
    flag it, fall back, and reproduce the resulting deadlock."""
    def burst_pingpong(n=8, depth=8):
        prog = Program("burst_pingpong", declared_type="A")
        cmd = prog.fifo("cmd", depth)
        resp = prog.fifo("resp", depth)

        @prog.module("ctrl")
        def ctrl():
            for i in range(n):
                yield Write(cmd, i)
            tot = 0
            for _ in range(n):
                tot += (yield Read(resp))
            yield Emit("sum", tot)

        @prog.module("proc")
        def proc():
            for _ in range(n):
                v = yield Read(cmd)
                yield Write(resp, 2 * v)

        return prog

    base = simulate(burst_pingpong())
    D = np.array([(1, 1), (2, 2), (1, 8), (8, 1), (4, 4), (8, 8)])
    out = resimulate_batch(base, D)
    _assert_batch_exact(out, burst_pingpong, D, simulate(burst_pingpong()))
    assert "cycle" in out.reasons[0] and "cycle" in out.reasons[1]
    assert out.results[0].deadlock            # fallback finds the deadlock
    assert out.ok[2:].all()                   # one roomy channel suffices


def test_batch_no_fallback_mode():
    base = simulate(producer_consumer(n=32, depth=4))
    out = resimulate_batch(base, np.array([[1], [16]]), fallback=False)
    for k in range(2):
        if not out.ok[k]:
            assert out.results[k] is None and out.cycles[k] == -1


# -------------------------------------------------------------- backends
def test_batch_reference_backend_agrees():
    """The production Gauss-Seidel solver against the Jacobi oracle."""
    base = simulate(skynet_like(items=32, depth=5))
    rng = np.random.default_rng(3)
    D = rng.integers(1, 10, size=(16, len(base.depths)))
    out = resimulate_batch(base, D)
    ref = resimulate_batch(base, D, backend="reference")
    assert (out.ok == ref.ok).all()
    assert (out.cycles == ref.cycles).all()
    assert (out.status == ref.status).all()


def test_batch_jax_backend_agrees():
    """Sparse chain-structured jax backend (Pallas interpret mode)."""
    pytest.importorskip("jax")
    base = simulate(producer_consumer(n=24, depth=3))
    D = np.array([[1], [2], [4], [8]])
    out = resimulate_batch(base, D, backend="numpy")
    jx = resimulate_batch(base, D, backend="jax")
    assert (out.ok == jx.ok).all()
    assert (out.cycles == jx.cycles).all()


# ------------------------------------------------------------- throughput
def test_batch_speedup_256_configs():
    """Acceptance: >= 256 skynet_like depth configs, batched >= 10x faster
    than the resimulate() loop, every config's cycle count exact against a
    from-scratch simulate()."""
    import time

    builder = lambda: skynet_like(items=128, depth=8)
    base = simulate(builder())
    rng = np.random.default_rng(0)
    K = 256
    D = rng.integers(4, 17, size=(K, len(base.depths)))
    # warm the shared compiled-graph cache for both paths
    resimulate(base, tuple(int(d) for d in D[0]))
    resimulate_batch(base, D[:2])

    # best-of-3 on both sides: single-shot wall timings are noisy enough
    # on shared CI boxes to trip the ratio assertion spuriously
    t_loop = float("inf")
    t_batch = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        looped = [resimulate(base, tuple(int(d) for d in row),
                             fallback=False) for row in D]
        t_loop = min(t_loop, time.perf_counter() - t0)
        t0 = time.perf_counter()
        out_nf = resimulate_batch(base, D, fallback=False)
        t_batch = min(t_batch, time.perf_counter() - t0)
    out = resimulate_batch(base, D)        # untimed: exercises fallback too

    # config-for-config agreement with the looped path
    for k, inc in enumerate(looped):
        assert inc.ok == bool(out.ok[k]) == bool(out_nf.ok[k]), \
            (k, out.reasons[k], inc.reason)
        if inc.ok:
            assert inc.result.cycles == out.cycles[k] == out_nf.cycles[k], k
    # cycle counts exact against from-scratch simulation for EVERY config:
    # reused ones from the shared fixpoint, violated ones via fallback
    for k in range(K):
        full = simulate(builder(), depths=tuple(int(d) for d in D[k]))
        assert out.cycles[k] == full.cycles, (k, "reused" if out.ok[k]
                                              else out.reasons[k])
    speedup = t_loop / t_batch
    assert speedup >= 10.0, (
        f"batched DSE only {speedup:.1f}x over looped resimulate "
        f"({t_loop*1e3:.0f} ms vs {t_batch*1e3:.0f} ms for {K} configs)")


def test_batch_jax_dense_backend_agrees():
    """Legacy dense lowering (backend="jax_dense") still matches numpy."""
    pytest.importorskip("jax")
    base = simulate(producer_consumer(n=24, depth=3))
    D = np.array([[1], [2], [4], [8]])
    out = resimulate_batch(base, D, backend="numpy")
    jd = resimulate_batch(base, D, backend="jax_dense")
    assert (out.ok == jd.ok).all()
    assert (out.cycles == jd.cycles).all()


def test_batch_jax_sparse_deadlock_and_war_cycle():
    """The sparse jax lane must classify starved writes (DEADLOCK) and
    inverted event orders (WAR CYCLE) bit-identically to numpy — the
    failure verdicts, not just the happy path."""
    pytest.importorskip("jax")

    def leftover():
        prog = Program("leftover", declared_type="A")
        d = prog.fifo("d", 8)

        @prog.module("p")
        def p():
            for i in range(8):
                yield Write(d, i)

        @prog.module("c")
        def c():
            tot = 0
            for _ in range(4):
                tot += (yield Read(d))
            yield Emit("sum", tot)

        return prog

    def burst_pingpong(n=8, depth=8):
        prog = Program("burst_pingpong", declared_type="A")
        cmd = prog.fifo("cmd", depth)
        resp = prog.fifo("resp", depth)

        @prog.module("ctrl")
        def ctrl():
            for i in range(n):
                yield Write(cmd, i)
            tot = 0
            for _ in range(n):
                tot += (yield Read(resp))
            yield Emit("sum", tot)

        @prog.module("proc")
        def proc():
            for _ in range(n):
                v = yield Read(cmd)
                yield Write(resp, 2 * v)

        return prog

    cases = [(leftover, np.array([[8], [4], [3], [1]])),
             (burst_pingpong, np.array([(1, 1), (2, 2), (1, 8), (8, 1),
                                        (4, 4), (8, 8)]))]
    for builder, D in cases:
        base = simulate(builder())
        o_np = resimulate_batch(base, D, backend="numpy", fallback=False)
        o_jx = resimulate_batch(base, D, backend="jax", fallback=False)
        assert (o_np.status == o_jx.status).all(), builder.__name__
        assert (o_np.cycles == o_jx.cycles).all(), builder.__name__
        assert (o_np.violated == o_jx.violated).all(), builder.__name__
    # the failure modes really were exercised
    assert (resimulate_batch(simulate(leftover()), np.array([[1]]),
                             backend="jax", fallback=False).status == 1).all()


# ------------------------------------------- dense-path regression fixes
def test_dense_jax_chunks_by_block(monkeypatch):
    """Regression: a batch larger than the dense capacity must be slab-
    chunked (honoring ``block``), not rejected outright."""
    pytest.importorskip("jax")
    import repro.core.dse as dse

    base = simulate(producer_consumer(n=24, depth=3))
    D = np.array([[1], [2], [3], [4], [6], [8]])
    # npad = 128 -> one config occupies exactly the capacity: the old
    # code raised for any K > 1, the fixed path chunks into slabs of 1
    monkeypatch.setattr(dse, "_DENSE_CAP", 128 * 128)
    out = resimulate_batch(base, D, backend="numpy")
    jd = resimulate_batch(base, D, backend="jax_dense")
    assert (out.ok == jd.ok).all()
    assert (out.cycles == jd.cycles).all()
    assert (out.violated == jd.violated).all()


def test_dense_jax_single_config_capacity_error(monkeypatch):
    """Only a SINGLE config exceeding dense capacity is an error — and the
    message must point at a usable backend."""
    pytest.importorskip("jax")
    import repro.core.dse as dse

    base = simulate(producer_consumer(n=24, depth=3))
    monkeypatch.setattr(dse, "_DENSE_CAP", 128 * 128 - 1)
    with pytest.raises(ValueError, match="numpy"):
        resimulate_batch(base, np.array([[4]]), backend="jax_dense")


def test_jax_backends_refuse_int32_overflow():
    """Regression: both jax lanes must refuse (not silently wrap) a graph
    whose path-length bound exceeds int32 headroom."""
    pytest.importorskip("jax")
    from repro.core.dse import _batch_arrays
    from repro.core.incremental import compile_graph

    base = simulate(producer_consumer(n=24, depth=3))
    g = compile_graph(base.graph)
    ba = _batch_arrays(g)
    old = ba.bound
    try:
        ba.bound = 1 << 28              # numpy's int64 switchover point
        for b in ("jax", "jax_dense"):
            with pytest.raises(ValueError, match="numpy"):
                resimulate_batch(base, np.array([[4]]), backend=b,
                                 fallback=False)
    finally:
        ba.bound = old


def test_reused_shells_do_not_alias_mutable_state():
    """Regression: REUSED result shells shared the base run's mutable
    ``stats`` object and ``constraints`` list — mutating one sweep result
    corrupted its siblings and the cached base run."""
    base = simulate(producer_consumer(n=32, depth=4))
    D = np.array([[4], [8], [16]])
    out = resimulate_batch(base, D)
    assert out.ok.all()
    r0, r1 = out.results[0], out.results[1]
    assert r0.stats is not base.stats
    assert r0.stats is not r1.stats
    before = r1.stats.queries
    r0.stats.queries = -123
    r0.constraints.append("sentinel")
    assert r1.stats.queries == before
    assert base.stats.queries != -123
    assert "sentinel" not in r1.constraints
    assert "sentinel" not in base.constraints
