"""Shared pytest plumbing.

``--regen-golden`` switches the golden-trace conformance suite
(``tests/test_golden.py``) from *asserting* against the checked-in
reference results to *rewriting* them from the generator engine — so an
intentional behavior change is one command away and shows up as a
reviewable diff of ``tests/golden/*.json``::

    PYTHONPATH=src python -m pytest -m golden --regen-golden
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--regen-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json from the generator engine "
             "instead of asserting against them")
    parser.addoption(
        "--corpus-seeds", type=int, default=8, metavar="N",
        help="seeds per scale for the big corpus sweep (-m corpus)")
    parser.addoption(
        "--corpus-scale", type=int, default=100, metavar="MODULES",
        help="module-count target for the big corpus sweep (-m corpus)")


@pytest.fixture
def regen_golden(request):
    return request.config.getoption("--regen-golden")


@pytest.fixture
def corpus_seeds(request):
    return request.config.getoption("--corpus-seeds")


@pytest.fixture
def corpus_scale(request):
    return request.config.getoption("--corpus-scale")
