"""Core engine behaviour: OmniSim vs the cycle-stepped RTL oracle."""
import pytest

from repro.core import (LightningSim, Program, Read, ReadNB, Write, WriteNB,
                        Delay, Emit, Empty, Full, UnsupportedDesignError,
                        simulate, simulate_rtl)


def _pc(n=16, depth=2, consumer_delay=0):
    prog = Program("pc", declared_type="A")
    data = prog.fifo("data", depth)

    @prog.module("producer")
    def producer():
        for i in range(1, n + 1):
            yield Write(data, i)

    @prog.module("consumer")
    def consumer():
        total = 0
        for _ in range(n):
            total += (yield Read(data))
            if consumer_delay:
                yield Delay(consumer_delay)
        yield Emit("sum", total)

    return prog


def test_basic_producer_consumer_matches_oracle():
    r1 = simulate(_pc())
    r2 = simulate_rtl(_pc())
    assert r1.outputs == r2.outputs
    assert r1.cycles == r2.cycles
    assert r1.outputs["sum"] == 16 * 17 // 2


@pytest.mark.parametrize("depth", [1, 2, 3, 7, 100])
@pytest.mark.parametrize("delay", [0, 1, 3])
def test_depth_delay_sweep_matches_oracle(depth, delay):
    r1 = simulate(_pc(depth=depth, consumer_delay=delay))
    r2 = simulate_rtl(_pc(depth=depth, consumer_delay=delay))
    assert r1.outputs == r2.outputs
    assert r1.cycles == r2.cycles


def test_blocking_write_stalls_on_full_fifo():
    """depth=1 + slow consumer: writes must serialize behind reads."""
    r_fast = simulate(_pc(depth=100, consumer_delay=2))
    r_slow = simulate(_pc(depth=1, consumer_delay=2))
    assert r_slow.cycles >= r_fast.cycles
    assert r_slow.outputs == r_fast.outputs


def test_nb_write_drop_semantics():
    prog = Program("nbdrop", declared_type="C")
    f = prog.fifo("f", 1)

    @prog.module("p")
    def p():
        sent = 0
        for i in range(10):
            ok = yield WriteNB(f, i)
            if ok:
                sent += 1
        yield Emit("sent", sent)

    @prog.module("c")
    def c():
        got = []
        for _ in range(3):
            v = yield Read(f)
            got.append(v)
            yield Delay(2)
        yield Emit("got", tuple(got))

    r1 = simulate(prog)
    prog2 = Program("nbdrop", declared_type="C")
    # rebuild (generators are single-use)
    r2 = simulate_rtl(_rebuild_nbdrop())
    assert r1.outputs == r2.outputs
    assert r1.cycles == r2.cycles
    assert r1.outputs["sent"] >= 3           # at least what the consumer got


def _rebuild_nbdrop():
    prog = Program("nbdrop", declared_type="C")
    f = prog.fifo("f", 1)

    @prog.module("p")
    def p():
        sent = 0
        for i in range(10):
            ok = yield WriteNB(f, i)
            if ok:
                sent += 1
        yield Emit("sent", sent)

    @prog.module("c")
    def c():
        got = []
        for _ in range(3):
            v = yield Read(f)
            got.append(v)
            yield Delay(2)
        yield Emit("got", tuple(got))

    return prog


def test_nb_read_polling():
    def build():
        prog = Program("poll", declared_type="B")
        f = prog.fifo("f", 2)

        @prog.module("p")
        def p():
            yield Delay(10)
            yield Write(f, 42)

        @prog.module("c")
        def c():
            polls = 0
            while True:
                ok, v = yield ReadNB(f)
                polls += 1
                if ok:
                    break
            yield Emit("polls", polls)
            yield Emit("v", v)

        return prog

    r1 = simulate(build())
    r2 = simulate_rtl(build())
    assert r1.outputs == r2.outputs == {"polls": 12, "v": 42}
    assert r1.cycles == r2.cycles


def test_empty_full_probes():
    def build():
        prog = Program("probe", declared_type="C")
        f = prog.fifo("f", 2)

        @prog.module("p")
        def p():
            outcomes = []
            for i in range(6):
                full = yield Full(f)
                outcomes.append(full)
                if not full:
                    yield Write(f, i)
            yield Emit("full_seq", tuple(outcomes))

        @prog.module("c")
        def c():
            got = 0
            for _ in range(3):
                v = yield Read(f)
                got += 1
                yield Delay(5)
            yield Emit("got", got)

        return prog

    r1 = simulate(build())
    r2 = simulate_rtl(build())
    assert r1.outputs == r2.outputs
    assert r1.cycles == r2.cycles


def test_deadlock_detected_not_hang():
    def build():
        prog = Program("dl", declared_type="B")
        ab = prog.fifo("ab", 1)
        ba = prog.fifo("ba", 1)

        @prog.module("a")
        def a():
            v = yield Read(ba)
            yield Write(ab, v)

        @prog.module("b")
        def b():
            v = yield Read(ab)
            yield Write(ba, v)

        return prog

    r1 = simulate(build())
    assert r1.deadlock
    assert set(r1.outputs["__deadlock__"]) == {"a", "b"}
    r2 = simulate_rtl(build())
    assert r2.deadlock


def test_deadlock_from_undersized_fifo():
    """Cyclic design that only deadlocks when the FIFO is too small."""
    def build(depth):
        prog = Program("dl2", declared_type="B")
        req = prog.fifo("req", depth)
        resp = prog.fifo("resp", 2)

        @prog.module("ctrl")
        def ctrl():
            total = 0
            # sends a burst of 3 before draining any response
            for i in range(3):
                yield Write(req, i)
            for i in range(3):
                total += (yield Read(resp))
            yield Emit("total", total)

        @prog.module("proc")
        def proc():
            for _ in range(3):
                v = yield Read(req)
                yield Write(resp, v * 10)

        return prog

    ok = simulate(build(3))
    assert not ok.deadlock and ok.outputs["total"] == 30
    # depth=2 still fine: proc drains as ctrl writes
    ok2 = simulate(build(2))
    assert not ok2.deadlock
    rtl = simulate_rtl(build(3))
    assert ok.cycles == rtl.cycles


def test_forced_earliest_query_rule():
    """Two pollers whose targets are mutually unknown: the earliest pending
    query must resolve false, guaranteeing forward progress."""
    def build():
        prog = Program("mutual_poll", declared_type="C")
        ab = prog.fifo("ab", 1)
        ba = prog.fifo("ba", 1)

        @prog.module("a")
        def a():
            sent = False
            while True:
                ok, _ = yield ReadNB(ba)
                if ok:
                    break
                if not sent:
                    yield WriteNB(ab, 1)
                    sent = True
            yield Emit("a_done", True)

        @prog.module("b")
        def b():
            while True:
                ok, _ = yield ReadNB(ab)
                if ok:
                    break
            yield WriteNB(ba, 2)
            yield Emit("b_done", True)

        return prog

    r1 = simulate(build())
    r2 = simulate_rtl(build())
    assert r1.outputs == r2.outputs == {"a_done": True, "b_done": True}
    assert r1.cycles == r2.cycles
    assert r1.stats.queries_forced_false >= 1


def test_finalization_matches_eager_times():
    # _finish asserts longest-path == eager times internally; just run a
    # design with heavy stalling to exercise it.
    r = simulate(_pc(n=64, depth=1, consumer_delay=3))
    assert r.cycles > 64


def test_shuffle_schedule_independence():
    base = simulate(_pc(n=32, depth=2, consumer_delay=1))
    for seed in range(8):
        r = simulate(_pc(n=32, depth=2, consumer_delay=1), shuffle_seed=seed)
        assert r.outputs == base.outputs
        assert r.cycles == base.cycles


def test_lightningsim_rejects_nb():
    prog = Program("nb", declared_type="C")
    f = prog.fifo("f", 2)

    @prog.module("p")
    def p():
        yield WriteNB(f, 1)

    @prog.module("c")
    def c():
        yield ReadNB(f)

    with pytest.raises(UnsupportedDesignError):
        LightningSim(prog).run()


def test_forced_false_tie_same_cycle():
    """Two symmetric pollers issue queries at the same cycle: the earliest-
    query rule must break the tie deterministically and — because any event
    committing after the tied cycle can satisfy neither query — the
    resolution order must not matter.  Generator, shuffled-generator,
    hybrid and RTL oracle all agree, including the forced-false count."""
    def build():
        prog = Program("tie", declared_type="C")
        ab = prog.fifo("ab", 1)
        ba = prog.fifo("ba", 1)

        @prog.module("a")
        def a():
            hits = 0
            for _ in range(6):
                ok, _v = yield ReadNB(ba)
                hits += int(ok)
            yield WriteNB(ab, 1)
            yield Emit("a_hits", hits)

        @prog.module("b")
        def b():
            hits = 0
            for _ in range(6):
                ok, _v = yield ReadNB(ab)
                hits += int(ok)
            yield WriteNB(ba, 2)
            yield Emit("b_hits", hits)

        return prog

    g = simulate(build(), trace="never")
    h = simulate(build(), trace="always")
    r = simulate_rtl(build())
    assert h.engine == "omnisim-hybrid"
    assert g.outputs == h.outputs == r.outputs
    assert g.cycles == h.cycles == r.cycles
    # identical SimStats on both paths — the tie is resolved the same way
    assert g.stats.queries == h.stats.queries
    assert g.stats.queries_forced_false == h.stats.queries_forced_false >= 2
    assert g.stats.nodes == h.stats.nodes
    assert g.stats.edges == h.stats.edges
    for seed in range(4):
        s = simulate(build(), trace="never", shuffle_seed=seed)
        assert s.outputs == g.outputs and s.cycles == g.cycles


def test_forced_false_tie_same_cycle_under_periodization():
    """Same-cycle forced-false ties with long periodic streaks: the poll
    detector arms on both symmetric pollers, but undecidable outcomes must
    never burst (the target event is uncommitted), so every resolution
    still goes through the earliest-query rule — and when a mid-run write
    finally lands, the burst window must stop exactly at the first poll
    whose outcome flips.  Generator, periodized hybrid, un-periodized
    hybrid and the RTL oracle all agree, including forced-false counts."""
    from repro.core.trace import simulate_hybrid

    def build():
        prog = Program("tie_periodized", declared_type="C")
        ab = prog.fifo("ab", 1)
        ba = prog.fifo("ba", 1)

        @prog.module("a")              # 14 tight polls: streak >= 3 arms
        def a():
            hits = 0
            for _ in range(14):
                ok, _v = yield ReadNB(ba)
                hits += int(ok)
            yield WriteNB(ab, 1)       # lands mid-way through b's loop
            yield Emit("a_hits", hits)

        @prog.module("b")
        def b():
            hits = 0
            for _ in range(14):
                ok, _v = yield ReadNB(ab)
                hits += int(ok)
            yield WriteNB(ba, 2)
            yield Emit("b_hits", hits)

        return prog

    g = simulate(build(), trace="never")
    hp = simulate_hybrid(build(), periodize=True)
    hn = simulate_hybrid(build(), periodize=False)
    r = simulate_rtl(build())
    assert g.outputs == hp.outputs == hn.outputs == r.outputs
    assert g.cycles == hp.cycles == hn.cycles == r.cycles
    assert g.stats.queries == hp.stats.queries == hn.stats.queries
    assert (g.stats.queries_forced_false == hp.stats.queries_forced_false
            == hn.stats.queries_forced_false >= 2)
    assert g.stats.nodes == hp.stats.nodes and g.stats.edges == hp.stats.edges


def test_periodized_burst_stops_at_outcome_flip():
    """A poller whose target write lands mid-loop: the periodizer may bulk-
    resolve only the polls strictly before the write's commit cycle — the
    flip poll and everything after go through per-query resolution, so the
    hit count and every stat match the generator engine exactly."""
    from repro.core.trace import simulate_hybrid

    def build():
        prog = Program("flip", declared_type="C")
        sig = prog.fifo("sig", 2)

        @prog.module("poller")
        def poller():
            hits = 0
            polls = 0
            while hits < 2 and polls < 60:
                ok, _v = yield ReadNB(sig)
                polls += 1
                hits += int(ok)
            yield Emit("polls", polls)
            yield Emit("hits", hits)

        @prog.module("writer")
        def writer():
            yield Delay(17)
            yield Write(sig, 1)        # flips the poller's 18th-ish poll
            yield Delay(23)
            yield Write(sig, 2)

        return prog

    g = simulate(build(), trace="never")
    h = simulate_hybrid(build())
    assert g.outputs == h.outputs and g.cycles == h.cycles
    assert g.stats.queries == h.stats.queries
    assert g.stats.queries_forced_false == h.stats.queries_forced_false
    assert h.stats.queries_periodized > 0          # bursts actually fired
    assert h.stats.queries_periodized < h.stats.queries
    assert g.outputs["hits"] == 2


@pytest.mark.parametrize("name", ["multisite_poll", "nb_success_stream"])
def test_periodized_accounting_multisite_and_success(name):
    """The generalized periodizer's accounting on its two new pattern
    classes: multi-site (site, gap) tuples and steady NB-success streams.
    ``queries_periodized`` must equal the engine's bulk counter, stay
    within the query total, and the periodized/per-query/generator paths
    must agree on every semantic stat."""
    from repro.core.trace import simulate_hybrid
    from repro.designs.dynamic import DYNAMIC_DESIGNS

    b = lambda: DYNAMIC_DESIGNS[name](items=256)
    g = simulate(b(), trace="never")
    hp = simulate_hybrid(b(), periodize=True)
    hn = simulate_hybrid(b(), periodize=False)
    assert g.outputs == hp.outputs == hn.outputs
    assert g.cycles == hp.cycles == hn.cycles
    assert g.stats.queries == hp.stats.queries == hn.stats.queries
    assert (g.stats.queries_forced_false == hp.stats.queries_forced_false
            == hn.stats.queries_forced_false)
    info = hp.graph._hybrid
    assert hp.stats.queries_periodized == info["bulk_queries"]
    assert 0 < hp.stats.queries_periodized <= hp.stats.queries
    assert info["bursts"] > 0
    assert hn.stats.queries_periodized == 0
    # most polls in these steady-state designs are bulk-resolved
    assert hp.stats.queries_periodized * 2 > hp.stats.queries


def test_dead_probe_elimination():
    def build(used):
        prog = Program("deadprobe", declared_type="C")
        f = prog.fifo("f", 2)

        @prog.module("p")
        def p():
            for i in range(4):
                yield Full(f, used=used)     # result discarded when unused
                yield Write(f, i)

        @prog.module("c")
        def c():
            total = 0
            for _ in range(4):
                total += (yield Read(f))
            yield Emit("total", total)

        return prog

    r_used = simulate(build(True))
    r_dead = simulate(build(False))
    # same timing and outputs, but no queries issued for the dead probes
    assert r_used.outputs == r_dead.outputs
    assert r_used.cycles == r_dead.cycles
    assert r_dead.stats.skipped_probes == 4
    assert r_dead.stats.queries < r_used.stats.queries
