"""Property-based tests (hypothesis) on the simulator's invariants.

Invariants tested on randomized dataflow programs:
  1. OmniSim == cycle-stepped RTL oracle (functionality + cycle count) for
     arbitrary pipelines with random depths/delays and NB accesses.
  2. Results are independent of the coroutine servicing order (the paper's
     central claim vs OS scheduling).
  3. The decoupled baseline agrees on Type A programs.
  4. Longest-path backends agree on random DAGs.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.property   # opt-in tier: pytest -m property

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Delay, Emit, LightningSim, Program, Read, ReadNB,
                        Write, WriteNB, level_schedule, longest_path_numpy,
                        longest_path_python, simulate, simulate_rtl)


# --------------------------------------------------------------- generators
def build_chain(n_items, stage_delays, depths, nb_flags):
    """A pipeline chain: source -> stage_1 .. stage_k -> sink.

    Stage i forwards with `stage_delays[i]` extra cycles; `nb_flags[i]`
    makes its *write* non-blocking (dropping on full -> Type C)."""
    prog = Program("rand_chain", declared_type="C" if any(nb_flags) else "A")
    chans = [prog.fifo(f"c{i}", depths[i]) for i in range(len(stage_delays) + 1)]

    @prog.module("source")
    def source():
        for i in range(n_items):
            yield Write(chans[0], i + 1)

    def make_stage(s):
        def stage():
            delay = stage_delays[s]
            fwd = 0
            for _ in range(n_items):
                v = yield Read(chans[s])
                if delay:
                    yield Delay(delay)
                if nb_flags[s]:
                    ok = yield WriteNB(chans[s + 1], v)
                    if ok:
                        fwd += 1
                else:
                    yield Write(chans[s + 1], v)
                    fwd += 1
            yield Emit(f"fwd{s}", fwd)
        return stage

    for s in range(len(stage_delays)):
        prog.add_module(f"stage{s}", make_stage(s))

    @prog.module("sink")
    def sink():
        total = 0
        polls = 0
        # NB stages may drop; the sink polls a bounded number of cycles
        for _ in range(n_items * (max(stage_delays, default=0) + 2) + 16):
            ok, v = yield ReadNB(chans[-1])
            polls += 1
            if ok:
                total += v
        yield Emit("total", total)

    return prog


chain_params = st.tuples(
    st.integers(min_value=3, max_value=24),                      # n_items
    st.lists(st.integers(0, 3), min_size=1, max_size=4),         # stage delays
    st.integers(min_value=1, max_value=4),                       # depth seed
    st.lists(st.booleans(), min_size=1, max_size=4),             # nb flags
)


@settings(max_examples=40, deadline=None)
@given(chain_params)
def test_omnisim_matches_rtl_oracle(params):
    n_items, delays, depth, nb = params
    k = len(delays)
    nb = (nb * k)[:k]
    depths = [depth] * (k + 1)
    r1 = simulate(build_chain(n_items, delays, depths, nb))
    r2 = simulate_rtl(build_chain(n_items, delays, depths, nb))
    assert r1.outputs == r2.outputs
    assert r1.cycles == r2.cycles


@settings(max_examples=20, deadline=None)
@given(chain_params, st.integers(min_value=0, max_value=2**31 - 1))
def test_schedule_independence(params, seed):
    n_items, delays, depth, nb = params
    k = len(delays)
    nb = (nb * k)[:k]
    depths = [depth] * (k + 1)
    base = simulate(build_chain(n_items, delays, depths, nb))
    shuf = simulate(build_chain(n_items, delays, depths, nb), shuffle_seed=seed)
    assert base.outputs == shuf.outputs
    assert base.cycles == shuf.cycles


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 40), st.lists(st.integers(0, 3), min_size=1, max_size=4),
       st.integers(1, 5))
def test_typea_three_engines_agree(n_items, delays, depth):
    def build():
        prog = Program("typea_rand", declared_type="A")
        chans = [prog.fifo(f"c{i}", depth) for i in range(len(delays) + 1)]

        @prog.module("source")
        def source():
            for i in range(n_items):
                yield Write(chans[0], i * 3 + 1)

        def mk(s):
            def stage():
                for _ in range(n_items):
                    v = yield Read(chans[s])
                    if delays[s]:
                        yield Delay(delays[s])
                    yield Write(chans[s + 1], v + s)
            return stage

        for s in range(len(delays)):
            prog.add_module(f"st{s}", mk(s))

        @prog.module("sink")
        def sink():
            total = 0
            for _ in range(n_items):
                total += (yield Read(chans[-1]))
            yield Emit("total", total)

        return prog

    r1 = simulate(build())
    r2 = simulate_rtl(build())
    r3 = LightningSim(build()).run()
    assert r1.outputs == r2.outputs == r3.outputs
    assert r1.cycles == r2.cycles == r3.cycles


# ------------------------------------------------------------ graph backends
@st.composite
def random_dag(draw):
    n = draw(st.integers(min_value=1, max_value=120))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    indptr = [0]
    src, wgt = [], []
    for i in range(n):
        k = int(rng.integers(0, min(i, 4) + 1)) if i else 0
        preds = rng.choice(i, size=k, replace=False) if k else []
        for p in preds:
            src.append(int(p))
            wgt.append(int(rng.integers(0, 10)))
        indptr.append(len(src))
    base = rng.integers(0, 5, size=n)
    base[np.diff(indptr) > 0] = 0
    return (np.array(indptr), np.array(src, dtype=np.int64),
            np.array(wgt, dtype=np.int64), base.astype(np.int64))


@settings(max_examples=50, deadline=None)
@given(random_dag())
def test_longest_path_backends_agree(csr):
    indptr, src, wgt, base = csr
    t_py = longest_path_python(indptr, src, wgt, base)
    t_np = longest_path_numpy(indptr, src, wgt, base)
    assert np.array_equal(t_py, t_np)


@settings(max_examples=25, deadline=None)
@given(random_dag())
def test_level_schedule_is_valid(csr):
    indptr, src, _, _ = csr
    level, levels = level_schedule(indptr, src)
    seen = set()
    for group in levels:
        for node in group:
            for k in range(indptr[node], indptr[node + 1]):
                assert src[k] in seen, "pred scheduled after its dependent"
        seen.update(int(x) for x in group)
    assert len(seen) == len(indptr) - 1


# -------------------------------------------------- incremental equivalence
@settings(max_examples=20, deadline=None)
@given(st.integers(4, 30), st.lists(st.integers(0, 2), min_size=1, max_size=3),
       st.integers(1, 4), st.lists(st.integers(1, 12), min_size=2, max_size=2))
def test_incremental_equals_full_resim(n_items, delays, depth, new_depths):
    """For any program and any depth change, incremental re-simulation (or
    its constraint-violation fallback) must equal a from-scratch run."""
    from repro.core import resimulate

    k = len(delays)
    nb = [True] * k
    depths = [depth] * (k + 1)
    base = simulate(build_chain(n_items, delays, depths, nb))
    target = tuple((new_depths * (k + 1))[: k + 1])
    inc = resimulate(base, target)
    full = simulate(build_chain(n_items, delays, list(target), nb))
    assert inc.result.cycles == full.cycles
    assert inc.result.outputs == full.outputs
