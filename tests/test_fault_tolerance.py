"""Fault-tolerance substrate: checkpoint/restart, elastic re-mesh,
straggler detection, data-pipeline resume, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticTokenStream
from repro.distrib.checkpoint import CheckpointManager
from repro.distrib.elastic import StragglerMonitor, best_mesh_shape
from repro.optim.adamw import AdamWState, adamw_update, init_adamw
from repro.optim.compression import compress, decompress, init_residuals


# ------------------------------------------------------------- checkpointing
def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = _tree()
    opt = init_adamw(params)
    mgr.save(10, params, opt, extra={"data": {"step": 10, "seed": 0,
                                              "host_id": 0}})
    p2, o2, extra = mgr.restore(10, params, opt)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["data"]["step"] == 10
    assert int(o2.step) == int(opt.step)


def test_checkpoint_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, params)
    assert mgr.latest() == 4
    assert mgr.all_steps() == [3, 4]          # keep=2 garbage-collected


def test_checkpoint_atomicity(tmp_path):
    """A crashed save (leftover .tmp dir) must be invisible to latest()."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_000000000009.tmp"))
    assert mgr.latest() == 5                  # tmp dir ignored
    mgr.save(9, _tree())                      # overwrite stale tmp, publish
    assert mgr.latest() == 9


def test_training_resume_equivalence(tmp_path):
    """Train 4 steps straight vs 2 + checkpoint + restore + 2: identical."""
    params = {"w": jnp.ones((4, 4)) * 0.5}
    opt = init_adamw(params)

    def step(p, o, i):
        g = {"w": jnp.full((4, 4), 0.1 * (i + 1))}
        return adamw_update(g, o, p, lr=1e-2)

    p1, o1 = params, opt
    for i in range(4):
        p1, o1 = step(p1, o1, i)

    p2, o2 = params, opt
    for i in range(2):
        p2, o2 = step(p2, o2, i)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, p2, o2)
    p2r, o2r, _ = mgr.restore(2, p2, o2)
    for i in range(2, 4):
        p2r, o2r = step(p2r, o2r, i)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2r["w"]),
                               rtol=1e-6)


# -------------------------------------------------------------- elastic mesh
def test_best_mesh_shape_degraded_fleet():
    # full two pods
    assert best_mesh_shape(512) == ((2, 16, 16), ("pod", "data", "model"))
    # lost a pod -> single-pod mesh
    assert best_mesh_shape(272) == ((17, 16), ("data", "model"))
    # lost some hosts within the pod -> shrink 'data', keep 'model'
    shape, axes = best_mesh_shape(192)
    assert axes == ("data", "model") and shape == (12, 16)
    with pytest.raises(AssertionError):
        best_mesh_shape(8)                    # fewer than model shards


def test_straggler_monitor():
    mon = StragglerMonitor(straggler_factor=1.5, patience=3)
    for step in range(6):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)
        out = mon.stragglers()
    assert out == [2]


# ------------------------------------------------------------- data pipeline
def test_data_stream_resume_exact():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=7)
    a = SyntheticTokenStream(cfg)
    batches = [a.next_batch() for _ in range(5)]
    state = a.state()
    more_a = [a.next_batch() for _ in range(3)]

    b = SyntheticTokenStream(cfg)
    b.restore(state)
    more_b = [b.next_batch() for _ in range(3)]
    for x, y in zip(more_a, more_b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_data_stream_host_sharding():
    cfg = DataConfig(vocab_size=1000, seq_len=8, global_batch=8, seed=3)
    h0 = SyntheticTokenStream(cfg, host_id=0, num_hosts=2)
    h1 = SyntheticTokenStream(cfg, host_id=1, num_hosts=2)
    b0, b1 = h0.next_batch(), h1.next_batch()
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ------------------------------------------------------- gradient compression
def test_compression_error_feedback_converges():
    """Error feedback: the running sum of decompressed grads tracks the true
    sum (residual stays bounded)."""
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (64, 64))}
    res = init_residuals(grads)
    true_sum = jnp.zeros((64, 64))
    deco_sum = jnp.zeros((64, 64))
    for i in range(20):
        g = {"w": grads["w"] * (0.5 + 0.1 * i)}
        q, scales, res = compress(g, res)
        d = decompress(q, scales)
        true_sum = true_sum + g["w"]
        deco_sum = deco_sum + d["w"]
    # residual carries at most one step's quantization error
    err = float(jnp.abs(true_sum - deco_sum).max())
    scale = float(jnp.abs(true_sum).max())
    assert err / scale < 0.02
    q, scales, _ = compress(grads, init_residuals(grads))
    assert jax.tree.leaves(q)[0].dtype == jnp.int8
