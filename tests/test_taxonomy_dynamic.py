"""Dynamic B-vs-C divergence validation (semantic taxonomy boundary)."""
import pytest

from repro.core import classify_dynamic
from repro.designs.paper import (fig4_ex2, fig4_ex3, fig4_ex4a, fig4_ex4b,
                                 fig4_ex5, fig2_timer)
from repro.designs.typea import producer_consumer


def test_type_a_stays_a():
    c = classify_dynamic(lambda: producer_consumer(n=32))
    assert c.dtype == "A"


def test_type_b_no_divergence():
    # fig4_ex2: NB outcomes never alter the written sequence
    c = classify_dynamic(lambda: fig4_ex2(n=64))
    assert c.dtype == "B", c
    # fig4_ex3: blocking-only cyclic
    c = classify_dynamic(lambda: fig4_ex3(n=64))
    assert c.dtype == "B", c


def test_timer_no_witness_falls_back_to_declared():
    """fig2_timer's outputs are depth-invariant (the witness probe cannot
    see its cycle-dependence); the declared Type C must stand."""
    c = classify_dynamic(lambda: fig2_timer(n=64))
    assert c.dtype == "C" and c.declared == "C"


@pytest.mark.parametrize("builder", [
    lambda: fig4_ex4a(n=128),
    lambda: fig4_ex4b(n=128),
    lambda: fig4_ex5(n=128),
])
def test_type_c_divergence_detected(builder):
    c = classify_dynamic(builder)
    assert c.dtype == "C", c
