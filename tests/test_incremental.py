"""Incremental re-simulation (paper Sec. 7.2 / Table 6)."""
import pytest

from repro.core import resimulate, simulate
from repro.designs.paper import fig4_ex5
from repro.designs.typea import producer_consumer, skynet_like


def test_table6_fig4_ex5():
    """(2,2) -> (2,100): constraints hold, graph reused, result exact.
       (2,2) -> (100,2): constraints violated, full re-sim fallback."""
    r0 = simulate(fig4_ex5())
    inc = resimulate(r0, (2, 100))
    assert inc.ok, inc.reason
    full = simulate(fig4_ex5(), depths=(2, 100))
    assert inc.result.cycles == full.cycles
    assert inc.result.outputs == full.outputs

    r0b = simulate(fig4_ex5())
    inc2 = resimulate(r0b, (100, 2))
    assert not inc2.ok
    assert "constraint" in inc2.reason
    full2 = simulate(fig4_ex5(), depths=(100, 2))
    assert inc2.result.cycles == full2.cycles      # fallback re-sim correct
    assert inc2.result.outputs == full2.outputs
    # the two configurations genuinely diverge
    assert full2.outputs != full.outputs


@pytest.mark.parametrize("new_depths", [(1,), (2,), (3,), (8,), (64,)])
def test_incremental_depth_sweep_typea(new_depths):
    """Blocking-only design: every depth change must be incrementally
    replayable (no NB constraints to violate) and exact vs full re-sim."""
    r0 = simulate(producer_consumer(n=64, depth=4))
    inc = resimulate(r0, new_depths)
    full = simulate(producer_consumer(n=64, depth=new_depths[0]))
    if inc.ok:
        assert inc.result.cycles == full.cycles
    else:
        # undersized depths can invalidate event order; fallback must agree
        assert inc.result.cycles == full.cycles
    assert inc.result.outputs == full.outputs


def test_incremental_deep_pipeline():
    prog = skynet_like(items=128, depth=8)
    r0 = simulate(prog)
    depths = list(r0.depths)
    depths[3] = 64                       # widen one internal channel
    inc = resimulate(r0, depths)
    full = simulate(skynet_like(items=128, depth=8), depths=depths)
    assert inc.result.cycles == full.cycles
    assert inc.result.outputs == full.outputs


def test_incremental_detects_new_deadlock():
    """Shrinking a depth below feasibility must not report a bogus reuse."""
    r0 = simulate(producer_consumer(n=16, depth=2))
    # depth stays >=1: still feasible; depth change handled either way
    inc = resimulate(r0, (1,))
    full = simulate(producer_consumer(n=16, depth=1))
    assert inc.result.cycles == full.cycles
