"""Sweep-service subsystem (``repro/sweep``, ISSUE 5).

Conformance: every verdict the service streams must be exactly what a
direct ``resimulate_batch`` — and therefore a from-scratch ``simulate`` —
reports, for any block split, shard count/mode, arrival order or cache
state.  Scheduler edge cases (cancellation mid-sweep, priority-lane
ordering and non-starvation, cross-request coalescing, cache eviction)
are driven deterministically through manual-mode ``SweepService.step()``
— no sleeps, no real multi-host.  Process-pool sharding runs under the
``service`` marker (tier-1 keeps the threaded fallback).
"""
import pickle
import threading
import time

import numpy as np
import pytest

from repro.core import (program_fingerprint, resimulate_batch, simulate)
from repro.core import dse as dse_mod
from repro.core.dse import _batch_arrays, solve_block_status
from repro.core.incremental import compile_graph
from repro.designs.paper import fig4_ex5
from repro.designs.typea import producer_consumer, skynet_like
from repro.sweep import (BULK, CANCELLED, INTERACTIVE, GraphCache,
                         SweepService, grid_search, pareto_front,
                         random_search, successive_halving)


def _manual_service(**kw):
    kw.setdefault("autostart", False)
    return SweepService(**kw)


def _assert_outcome_equal(out, ref, note=""):
    assert (out.ok == ref.ok).all(), note
    assert (out.status == ref.status).all(), note
    assert (out.cycles == ref.cycles).all(), note
    for k in range(len(ref.ok)):
        if ref.results[k] is not None:
            assert out.results[k].outputs == ref.results[k].outputs, (note, k)
            assert out.results[k].deadlock == ref.results[k].deadlock, \
                (note, k)


# ------------------------------------------------------------- conformance
def test_service_matches_resimulate_batch_mixed_statuses():
    """fig4_ex5 mixes reuse, constraint flips and fallback re-sims; the
    served sweep must agree row-for-row under a tiny block size."""
    base = simulate(fig4_ex5())
    D = np.array([(2, 100), (100, 2), (2, 2), (1, 1), (64, 64), (2, 100)])
    ref = resimulate_batch(base, D)
    with _manual_service(block=2, shards=2) as svc:
        out = svc.sweep(fig4_ex5(), D)
    _assert_outcome_equal(out, ref, "fig4_ex5")
    assert not out.ok[1] and "constraint" in out.reasons[1]


def test_service_reports_deadlock_rows():
    """Configs that starve a committed blocking write must deadlock with
    the fallback reproducing the full report (as resimulate_batch does)."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    D = np.array([[8], [1], [2]])
    ref = resimulate_batch(base, D)
    with _manual_service(block=1) as svc:
        out = svc.sweep(builder(), D)
    _assert_outcome_equal(out, ref, "producer_consumer")


def test_service_block_split_and_arrival_order_invariant():
    builder = lambda: skynet_like(items=48, depth=6)
    base = simulate(builder())
    rng = np.random.default_rng(7)
    D = rng.integers(1, 13, size=(24, len(base.depths)))
    ref = resimulate_batch(base, D)
    for block, shards in ((1, 1), (5, 3), (64, 1)):
        with _manual_service(block=block, shards=shards) as svc:
            out = svc.sweep(builder(), D)
            _assert_outcome_equal(out, ref, f"block={block}")
            # warm cache + reversed arrival order: still bit-identical
            out2 = svc.sweep(builder(), D[::-1])
            assert (out2.cycles == ref.cycles[::-1]).all()
            assert (out2.status == ref.status[::-1]).all()


def test_shard_modes_bit_identical():
    builder = lambda: skynet_like(items=48, depth=6)
    rng = np.random.default_rng(3)
    D = rng.integers(2, 13, size=(32, len(builder().fifos)))
    outs = []
    for shards in (1, 4):
        with _manual_service(block=16, shards=shards,
                             mode="thread") as svc:
            outs.append(svc.sweep(builder(), D))
    assert (outs[0].cycles == outs[1].cycles).all()
    assert (outs[0].status == outs[1].status).all()


def test_service_jax_backend_lane_bit_identical():
    """backend="jax" shard lane: the sparse Pallas solver serves sweeps
    with verdicts bit-identical to the numpy lane (deadlock rows, too)."""
    pytest.importorskip("jax")
    builder = lambda: skynet_like(items=32, depth=5)
    base = simulate(builder())
    rng = np.random.default_rng(11)
    D = rng.integers(1, 10, size=(24, len(base.depths)))
    ref = resimulate_batch(base, D)
    with _manual_service(block=8, shards=2, backend="jax") as svc:
        out = svc.sweep(builder(), D)
    _assert_outcome_equal(out, ref, "jax lane")
    assert (out.violated == ref.violated).all()


@pytest.mark.service
def test_process_shard_mode_bit_identical():
    """mode="process": workers hold their own unpickled CompiledGraph."""
    builder = lambda: skynet_like(items=48, depth=6)
    rng = np.random.default_rng(3)
    D = rng.integers(2, 13, size=(32, len(builder().fifos)))
    with _manual_service(block=16, shards=1) as svc:
        ref = svc.sweep(builder(), D)
    with _manual_service(block=16, shards=2, mode="process") as svc:
        out = svc.sweep(builder(), D)
    assert (out.cycles == ref.cycles).all()
    assert (out.status == ref.status).all()


def test_streaming_is_per_config():
    """stream() yields one ConfigResult per row (indices complete), usable
    before the assembled outcome."""
    builder = lambda: producer_consumer(n=32, depth=2)
    D = np.array([[d] for d in (1, 2, 4, 8, 16)])
    with SweepService(block=2) as svc:
        seen = {}
        for cfg in svc.stream(builder(), D):
            seen[cfg.index] = cfg
        assert sorted(seen) == list(range(len(D)))
        for k, cfg in seen.items():
            full = simulate(builder(), depths=(int(D[k, 0]),))
            assert cfg.cycles == full.cycles


# ---------------------------------------------------------------- scheduler
def test_cancellation_mid_sweep():
    """Cancel after one block: delivered rows stay exact, the stream
    terminates, undelivered rows surface as CANCELLED."""
    builder = lambda: skynet_like(items=48, depth=6)
    base = simulate(builder())
    rng = np.random.default_rng(0)
    D = rng.integers(4, 13, size=(30, len(base.depths)))
    ref = resimulate_batch(base, D)
    with _manual_service(block=10) as svc:
        h = svc.submit(builder(), D, priority=BULK)
        assert svc.step()                    # block 1: rows 0..9
        h.cancel()
        svc.step()                           # reaps + finalizes
        out = h.result()
    assert (out.cycles[:10] == ref.cycles[:10]).all()
    assert (out.status[:10] == ref.status[:10]).all()
    assert (out.status[10:] == CANCELLED).all()
    assert (out.cycles[10:] == -1).all()
    assert h.done and h.cancelled
    st = svc.scheduler.stats()
    assert st["cancelled_rows"] == 20 and st["rows"] == 10


def test_priority_lane_preempts_bulk():
    """An interactive query submitted behind a long bulk sweep is served
    in the very next block."""
    bulk_b = lambda: skynet_like(items=48, depth=6)
    inter_b = lambda: producer_consumer(n=32, depth=2)
    Db = np.full((40, len(bulk_b().fifos)), 8, dtype=np.int64)
    Db += np.arange(40)[:, None] % 5         # distinct rows
    with _manual_service(block=8) as svc:
        hb = svc.submit(bulk_b(), Db, priority=BULK)
        svc.step()                           # bulk gets one block first
        hi = svc.submit(inter_b(), np.array([[2], [4]]))
        assert hi._req.priority == INTERACTIVE      # auto-assigned
        svc.step()                           # must serve interactive next
        assert hi._req.delivered == 2 and hi.done is False
        assert hb._req.delivered == 8        # bulk has NOT advanced
        while svc.step():
            pass
        assert hi.result().ok.all()
        assert hb.result().cycles.min() >= 0


def test_bulk_not_starved_by_interactive_flood():
    """After starvation_limit consecutive interactive blocks, one bulk
    block is forced through."""
    inter_b = lambda: producer_consumer(n=32, depth=2)
    bulk_b = lambda: skynet_like(items=48, depth=6)
    Db = np.full((32, len(bulk_b().fifos)), 8, dtype=np.int64)
    with _manual_service(block=4, starvation_limit=2) as svc:
        hb = svc.submit(bulk_b(), Db, priority=BULK)
        his = [svc.submit(inter_b(), np.array([[d], [d + 1]]))
               for d in range(1, 7)]
        for _ in range(3):
            svc.step()
        st = svc.scheduler.stats()
        assert st["blocks_interactive"] == 2 and st["blocks_bulk"] == 1
        assert hb._req.delivered > 0
        while svc.step():
            pass
        assert all(h.result().cycles.min() >= 0 for h in his)


def test_starvation_debt_resets_when_bulk_lane_empty():
    """Interactive blocks served while NO bulk waits must not bank
    starvation debt that lets a later bulk sweep preempt the lane."""
    inter_b = lambda: producer_consumer(n=32, depth=2)
    bulk_b = lambda: skynet_like(items=48, depth=6)
    with _manual_service(block=4, starvation_limit=1) as svc:
        for d in (1, 2, 3):              # 3 interactive blocks, bulk empty
            svc.submit(inter_b(), np.array([[d]]))
            svc.step()
        Db = np.full((16, len(bulk_b().fifos)), 8, dtype=np.int64)
        svc.submit(bulk_b(), Db, priority=BULK)
        hi = svc.submit(inter_b(), np.array([[4]]))
        svc.step()                       # interactive still goes first
        assert hi._req.delivered == 1
        while svc.step():
            pass


def test_coalescing_and_block_dedup_across_requests():
    """Two tenants sweeping the same design share blocks, and identical
    rows across them are solved once."""
    builder = lambda: producer_consumer(n=32, depth=2)
    D1 = np.array([[1], [2], [4]])
    D2 = np.array([[2], [4], [8]])           # overlaps D1 on {2, 4}
    with _manual_service(block=16) as svc:
        h1 = svc.submit(builder(), D1, priority=BULK)
        h2 = svc.submit(builder(), D2, priority=BULK)
        assert svc.step() and not svc.step()     # ONE coalesced block
        st = svc.scheduler.stats()
        assert st["blocks"] == 1
        assert st["rows"] == 6 and st["rows_unique"] == 4
        o1, o2 = h1.result(), h2.result()
    for out, D in ((o1, D1), (o2, D2)):
        for k in range(len(D)):
            full = simulate(builder(), depths=(int(D[k, 0]),))
            assert out.cycles[k] == full.cycles


def test_cancel_mid_queue_finalizes_promptly():
    """A cancelled request buried behind a long bulk queue must get its
    terminal sentinel at the next scheduling point, not after the queue
    ahead of it drains."""
    builder = lambda: skynet_like(items=48, depth=6)
    Da = np.full((40, len(builder().fifos)), 8, dtype=np.int64)
    Da += np.arange(40)[:, None] % 5
    with _manual_service(block=4) as svc:
        ha = svc.submit(builder(), Da, priority=BULK)
        hb = svc.submit(builder(), np.full((2, Da.shape[1]), 9), priority=BULK)
        hb.cancel()
        svc.step()                       # one block of A; B reaped here
        assert hb._req.finalized
        out = hb.result()                # returns immediately, no drain of A
        assert (out.status == CANCELLED).all()
        assert not ha.done


def test_empty_depth_matrix_completes_immediately():
    builder = lambda: producer_consumer(n=32, depth=2)
    with _manual_service(block=8) as svc:
        h = svc.submit(builder(), np.zeros((0, 1), dtype=np.int64))
        out = h.result()
        assert len(out.ok) == 0 and h.done
        assert not svc.step()            # nothing ever reached the lanes


def test_scheduler_fault_fails_requests_loudly():
    """A faulting block must not wedge clients: queued requests get their
    sentinel and result()/stream() raise instead of hanging forever."""
    builder = lambda: producer_consumer(n=32, depth=2)
    with SweepService(block=4) as svc:
        def boom(entry, Du, t_deadline=None):
            raise RuntimeError("injected solver fault")

        svc.scheduler._solve_unique = boom
        h = svc.submit(builder(), np.array([[2], [4]]))
        with pytest.raises(RuntimeError, match="injected solver fault"):
            h.result(timeout=10.0)
        # a later result() must re-raise, not fabricate a CANCELLED outcome
        with pytest.raises(RuntimeError, match="injected solver fault"):
            h.result(timeout=10.0)


def test_close_aborts_pending_requests():
    builder = lambda: producer_consumer(n=32, depth=2)
    svc = _manual_service(block=4)
    h = svc.submit(builder(), np.array([[2], [4]]))
    svc.close()                          # never stepped
    with pytest.raises(RuntimeError, match="service closed"):
        h.result()
    # and a closed service refuses new work instead of enqueuing it into
    # a loop that will never run
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(builder(), np.array([[2]]))


def test_cancelled_rows_skip_fallback_work(monkeypatch):
    """Rows owned only by a cancelled request must not pay for fallback
    re-simulations nobody will receive (cancel landing mid-solve)."""
    builder = lambda: producer_consumer(n=32, depth=4)
    sim_calls = []
    real_sim = dse_mod.simulate

    def counting_sim(program, **kw):
        sim_calls.append(kw.get("depths"))
        return real_sim(program, **kw)

    monkeypatch.setattr(dse_mod, "simulate", counting_sim)
    with _manual_service(block=8) as svc:
        # depth 1 deadlocks (leftover items) -> would need a fallback sim
        h = svc.submit(builder(), np.array([[1], [8]]), priority=BULK)
        blk = svc.scheduler._assemble()
        h.cancel()                       # lands while the block is in flight
        svc.scheduler._deliver(blk)
        assert sim_calls == []           # no engine work for a dead stream
        assert h._req.finalized


# -------------------------------------------------------------------- cache
def test_cache_hit_miss_eviction_stats():
    calls = []

    def counting_sim(program, **kw):
        calls.append(program.name)
        return simulate(program, **kw)

    cache = GraphCache(capacity=1)
    e1 = cache.get_or_build(producer_consumer(n=32, depth=2),
                            simulate_fn=counting_sim)
    # warm repeat: same content fingerprint, no new simulation
    e1b = cache.get_or_build(producer_consumer(n=32, depth=2),
                             simulate_fn=counting_sim)
    assert e1 is e1b and len(calls) == 1
    # different design evicts (capacity 1) ...
    cache.get_or_build(skynet_like(items=24, depth=4),
                       simulate_fn=counting_sim)
    st = cache.stats()
    assert st["evictions"] == 1 and st["size"] == 1
    # ... so the first design must rebuild
    cache.get_or_build(producer_consumer(n=32, depth=2),
                       simulate_fn=counting_sim)
    assert len(calls) == 3
    st = cache.stats()
    assert st["hits"] == 1 and st["misses"] == 3
    assert st["hit_rate"] == pytest.approx(0.25)


def test_cache_content_addressing():
    """Same builder + same args ⇒ same key; changing an argument that a
    module closure captures changes the key."""
    k1 = program_fingerprint(producer_consumer(n=32, depth=2))
    k2 = program_fingerprint(producer_consumer(n=32, depth=2))
    k3 = program_fingerprint(producer_consumer(n=48, depth=2))
    assert k1 == k2 and k1 != k3


def _closure_design(captured):
    from repro.core.program import Emit, Program, Read, Write

    prog = Program("closure_design", declared_type="A")
    d = prog.fifo("d", 2)

    @prog.module("p")
    def p():
        for i in range(4):
            yield Write(d, i)

    @prog.module("c")
    def c():
        tot = 0
        for _ in range(4):
            tot += (yield Read(d))
        yield Emit("sum", tot + (captured is not None))

    return prog


def test_fingerprint_closure_edge_cases():
    """Captured values must hash by CONTENT: deeply nested data beyond
    the recursion bound still distinguishes designs (never a false cache
    hit), and default-repr objects hash stably (never a guaranteed
    miss)."""
    def nest(v, levels=12):
        for _ in range(levels):
            v = [v]
        return v

    deep1 = program_fingerprint(_closure_design(nest(1)))
    deep2 = program_fingerprint(_closure_design(nest(2)))
    assert deep1 != deep2                # differs only below the bound

    class Cfg:                           # default object.__repr__
        def __init__(self, x):
            self.x = x

    a1 = program_fingerprint(_closure_design(Cfg(1)))
    a2 = program_fingerprint(_closure_design(Cfg(1)))
    b = program_fingerprint(_closure_design(Cfg(2)))
    assert a1 == a2                      # stable across instances
    assert a1 != b                       # but content-sensitive


def test_fingerprint_kwonly_defaults_and_globals():
    """Design identity that lives in __kwdefaults__ or module globals
    (not consts/closures) must still change the key."""
    from repro.core.program import Emit, Program

    def build(count):
        prog = Program("kwonly", declared_type="A")

        def gen(*, n=count):
            yield Emit("n", n)

        prog.add_module("m", gen)
        return prog

    assert program_fingerprint(build(3)) == program_fingerprint(build(3))
    assert program_fingerprint(build(3)) != program_fingerprint(build(7))

    glob = {"Emit": __import__("repro.core.program",
                               fromlist=["Emit"]).Emit, "N": 3}
    src = "def gen():\n    yield Emit('n', N)\n"

    def build_global(n):
        from repro.core.program import Program
        g = dict(glob, N=n)
        exec(src, g)
        prog = Program("globdesign", declared_type="A")
        prog.add_module("m", g["gen"])
        return prog

    assert (program_fingerprint(build_global(3))
            == program_fingerprint(build_global(3)))
    assert (program_fingerprint(build_global(3))
            != program_fingerprint(build_global(7)))

    # a global read only inside a nested lambda still counts
    src_nested = "def gen():\n    f = lambda: N\n    yield Emit('n', f())\n"

    def build_nested(n):
        from repro.core.program import Program
        g = dict(glob, N=n)
        exec(src_nested, g)
        prog = Program("nestedglob", declared_type="A")
        prog.add_module("m", g["gen"])
        return prog

    assert (program_fingerprint(build_nested(3))
            != program_fingerprint(build_nested(7)))

    # container TYPE is content: (4, 8) and [4, 8] must not collide
    assert (program_fingerprint(_closure_design((4, 8)))
            != program_fingerprint(_closure_design([4, 8])))


def test_successive_halving_memoizes_survivors():
    """Each round submits only never-seen configs: total rows solved by
    the service is strictly less than population x rounds."""
    builder = lambda: producer_consumer(n=24, depth=2)
    with _manual_service(block=32) as svc:
        out = successive_halving(svc, builder(), n0=8, rounds=3, eta=2,
                                 lo=1, hi=8, seed=3)
        rows = svc.scheduler.stats()["rows"]
    assert rows == len(out.depths) < 8 * 3


def test_cache_accepts_existing_base_result():
    base = simulate(producer_consumer(n=32, depth=2))
    cache = GraphCache()
    entry = cache.get_or_build(base)
    assert entry.result is base
    assert entry.graph is compile_graph(base.graph)


# ------------------------------------------------------------ picklability
def test_compiled_graph_and_batch_arrays_pickle():
    """Worker-process sharding ships CompiledGraph (and its lazily rebuilt
    _BatchArrays view) over pickle; solves must survive the round trip."""
    base = simulate(skynet_like(items=48, depth=6))
    graph = compile_graph(base.graph)
    ba = _batch_arrays(graph)
    ba2 = pickle.loads(pickle.dumps(ba))
    assert (ba2.perm == ba.perm).all() and ba2.bound == ba.bound
    rng = np.random.default_rng(5)
    D = rng.integers(2, 13, size=(8, len(base.depths)))
    s_ref, c_ref, v_ref, _ = solve_block_status(graph, D)
    g2 = pickle.loads(pickle.dumps(graph))
    s2, c2, v2, _ = solve_block_status(g2, D)
    assert (s2 == s_ref).all() and (c2 == c_ref).all() \
        and (v2 == v_ref).all()


# ------------------------------------------------------------------ search
def test_pareto_front_dominance():
    D = np.array([[1, 1], [2, 2], [3, 3], [4, 4], [2, 1]])
    C = np.array([100, 50, 50, 40, 60])
    front = pareto_front(D, C)
    assert front == [((1, 1), 2, 100), ((2, 1), 3, 60), ((2, 2), 4, 50),
                     ((4, 4), 8, 40)]
    # infeasible rows never enter
    feas = np.array([True, True, True, False, True])
    assert all(a != 8 for _d, a, _c in pareto_front(D, C, feas))


def test_grid_search_modes_and_exactness():
    builder = lambda: producer_consumer(n=32, depth=2)
    with _manual_service(block=8) as svc:
        uni = grid_search(svc, builder(), [1, 2, 4, 8])
        assert len(uni.depths) == 4 and uni.feasible.all()
        for row, cyc in zip(uni.depths, uni.cycles):
            assert simulate(builder(),
                            depths=tuple(int(x) for x in row)).cycles == cyc
        axes = grid_search(svc, builder(), [1, 4], mode="axes")
        assert len(axes.depths) == 1 + len(builder().fifos) * 2
        prod = grid_search(svc, builder(), [1, 2], mode="product")
        assert len(prod.depths) == 2
        with pytest.raises(ValueError):
            grid_search(svc, skynet_like(items=24, depth=4),
                        list(range(9)), mode="product", limit=10)


def test_random_search_finds_brute_force_best():
    builder = lambda: producer_consumer(n=24, depth=2)
    with _manual_service(block=16) as svc:
        out = random_search(svc, builder(), n=24, lo=1, hi=8, seed=2)
    base = simulate(builder())
    ref = resimulate_batch(base, out.depths)
    feas = ref.cycles >= 0
    assert out.best[1] == int(ref.cycles[feas].min())
    assert len(out.pareto) >= 1


def test_successive_halving_reduces_area():
    builder = lambda: skynet_like(items=24, depth=4)
    with _manual_service(block=32) as svc:
        out = successive_halving(svc, builder(), n0=8, rounds=3, eta=2,
                                 lo=1, hi=12, seed=4)
    assert out.rounds == 3 and out.feasible.any()
    # the frontier's cheapest point must undercut the cheapest round-0
    # feasible candidate (halving explored toward lower area)
    n0_area = out.depths[:8][out.feasible[:8]].sum(axis=1)
    assert out.pareto[0][1] <= int(n0_area.min())
    # every frontier point is exact
    for dv, _area, cyc in out.pareto:
        assert simulate(builder(), depths=dv).cycles == cyc


# ------------------------------------------- search-driver bugfixes (ISSUE 9)
def test_feasible_mask_excludes_service_terminal_statuses():
    """Regression: rows whose status is a service-level terminal verdict
    (FAULTED / TIMED_OUT / REJECTED / CANCELLED) must be infeasible even
    when the cycles field carries a stale non-negative value — the old
    mask excluded them only via the incidental ``cycles >= 0`` check."""
    from repro.core.dse import (BatchOutcome, CYCLE, FAULTED, REJECTED,
                                REUSED, TIMED_OUT)
    from repro.sweep.search import _feasible_mask

    status = np.array([REUSED, FAULTED, TIMED_OUT, REJECTED, CANCELLED,
                       CYCLE], dtype=np.int8)
    cycles = np.array([10, 11, 12, 13, 14, 15], dtype=np.int64)  # all stale>=0
    K = len(status)
    out = BatchOutcome(ok=status == REUSED, cycles=cycles, status=status,
                       violated=np.zeros(K, dtype=np.int64),
                       reasons=[""] * K, results=[None] * K, elapsed_s=0.0)
    feas = _feasible_mask(out)
    # REUSED is feasible; CYCLE was refined by an exact fallback (cycles
    # >= 0, no deadlock result) so it stays feasible; every service
    # terminal status is excluded regardless of its cycles field
    assert feas.tolist() == [True, False, False, False, False, True]


def test_search_driver_excludes_faulted_rows_under_injected_faults():
    """A persistently faulting shard terminates its rows FAULTED; the
    search driver must keep them out of the frontier and still finish."""
    builder = lambda: producer_consumer(n=32, depth=4)
    # chunk0's launch and its single retry both fault -> FAULTED rows
    inj = FaultInjector(seed=3).arm("shard.fault", at=[0, 2])
    with _manual_service(block=8, shards=2, min_shard_rows=1, injector=inj,
                         retry=RetryPolicy(max_attempts=2,
                                           backoff_s=0.0)) as svc:
        out = grid_search(svc, builder(), [1, 2, 3, 4, 5, 6, 7, 8])
    faulted = np.asarray(out.cycles) == -1
    assert faulted.any(), "injector never fired"
    assert not out.feasible[faulted].any()
    assert out.feasible[~faulted].all()
    front_depths = {dv for dv, _a, _c in out.pareto}
    for k in np.flatnonzero(faulted):
        assert tuple(int(x) for x in out.depths[k]) not in front_depths
    assert out.best is not None


def test_successive_halving_empty_population_is_well_formed():
    """Regression: ``n0 == 0`` used to crash in ``np.concatenate`` on an
    empty list; it must return an empty, well-formed SearchOutcome."""
    builder = lambda: producer_consumer(n=16, depth=2)
    with _manual_service(block=8) as svc:
        out = successive_halving(svc, builder(), n0=0, rounds=3, eta=2)
    assert out.depths.shape == (0, len(builder().fifos))
    assert len(out.cycles) == 0 and len(out.feasible) == 0
    assert out.pareto == [] and out.best is None
    assert out.rounds == 0
    assert out.summary().startswith("0 evaluated")


def test_successive_halving_all_infeasible_round_accounting():
    """Regression: an all-infeasible round-0 population breaks out of the
    loop — ``rounds`` must report the rounds actually run, not the
    requested budget."""
    def exchange(K=6):
        # write-K-then-read-K exchange: live at depth >= K (the base),
        # a true deadlock at every depth < K (all sampled candidates)
        from repro.core.program import Program, Read, Write
        prog = Program("sh_dead", declared_type="B")
        ab = prog.fifo("ab", K)
        ba = prog.fifo("ba", K)

        @prog.module("x")
        def x():
            for i in range(K):
                yield Write(ab, i)
            for _ in range(K):
                yield Read(ba)

        @prog.module("y")
        def y():
            for i in range(K):
                yield Write(ba, i)
            for _ in range(K):
                yield Read(ab)

        return prog

    with _manual_service(block=16) as svc:
        out = successive_halving(svc, exchange(), n0=4, rounds=5,
                                 eta=2, lo=1, hi=5, seed=1)
    assert not out.feasible.any() and out.best is None
    assert out.rounds == 1                       # broke after round 0
    assert len(out.depths) == len(out.cycles) == len(out.feasible)


def test_graph_blob_never_mutates_shared_graph_two_threads(monkeypatch):
    """Regression: ``CacheEntry.graph_blob`` used to null the shared
    ``graph.batch`` around pickling without holding the entry lock; a
    concurrent thread-shard solver could observe ``batch is None``
    mid-solve.  The blob must now be built from a copy."""
    import repro.sweep.cache as cache_mod

    base = simulate(producer_consumer(n=32, depth=2))
    cache = GraphCache()
    entry = cache.get_or_build(base)
    batch_view = entry.batch          # lazy: built on first solver access
    assert batch_view is not None and entry.graph.batch is batch_view

    real_dumps = pickle.dumps
    dumped_graph_batch = []

    def slow_dumps(obj, *a, **kw):
        # capture what a concurrent reader of the SHARED graph would see
        # exactly while the dump is in flight, and widen the race window
        dumped_graph_batch.append(entry.graph.batch)
        time.sleep(0.002)
        return real_dumps(obj, *a, **kw)

    monkeypatch.setattr(cache_mod.pickle, "dumps", slow_dumps)
    observed_none = threading.Event()
    stop = threading.Event()

    def shard_solver():
        while not stop.is_set():
            if entry.graph.batch is None:
                observed_none.set()
                return

    t = threading.Thread(target=shard_solver)
    t.start()
    try:
        for _ in range(20):
            entry._graph_blob = None             # force a fresh pickle
            blob = entry.graph_blob()
    finally:
        stop.set()
        t.join()
    assert not observed_none.is_set()
    assert all(b is batch_view for b in dumped_graph_batch)
    assert entry.graph.batch is batch_view
    g2 = pickle.loads(blob)
    assert g2.batch is None                      # blob still ships stripped
    assert g2.n == entry.graph.n


# ------------------------------------------------------- dse-level dedup
def test_resimulate_batch_dedups_solver_work(monkeypatch):
    """Satellite: identical depth rows are solved once — solver work (and
    fallback re-simulation) scales with UNIQUE configs."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    rows_seen = []
    real_solve = dse_mod._solve_block_numpy

    def counting_solve(ba, Db):
        rows_seen.append(len(Db))
        return real_solve(ba, Db)

    monkeypatch.setattr(dse_mod, "_solve_block_numpy", counting_solve)
    D = np.array([[1], [8], [1], [8], [1], [8], [1], [8]])
    out = resimulate_batch(base, D)
    assert out.n_unique == 2 and sum(rows_seen) == 2
    # duplicates share one result object and identical verdicts
    assert out.results[0] is out.results[2] is out.results[4]
    assert out.cycles[1] == out.cycles[3] == out.cycles[5]
    ref = resimulate_batch(base, D, dedup=False)
    assert sum(rows_seen) == 2 + len(D)          # dedup=False solves all
    assert (ref.cycles == out.cycles).all()
    assert (ref.status == out.status).all()


def test_resimulate_batch_dedups_fallbacks(monkeypatch):
    """A duplicated violating config pays for ONE full re-simulation."""
    base = simulate(fig4_ex5())
    sim_calls = []
    real_sim = dse_mod.simulate

    def counting_sim(program, **kw):
        sim_calls.append(kw.get("depths"))
        return real_sim(program, **kw)

    monkeypatch.setattr(dse_mod, "simulate", counting_sim)
    D = np.array([(100, 2)] * 6 + [(2, 100)])
    out = resimulate_batch(base, D)
    assert not out.ok[0] and out.ok[6]
    assert len(sim_calls) == 1                   # one fallback for 6 rows
    full = simulate(fig4_ex5(), depths=(100, 2))
    assert (out.cycles[:6] == full.cycles).all()


# ---------------------------------------------------------- fault tolerance
# ISSUE 6: every recovery path driven deterministically through the seeded
# FaultInjector in manual mode — no real crashes, no sleeps (the real-pool
# drills live under the `faults` marker below).  The invariants under test:
# no client stream ever hangs, every row ends in a definite status, and
# rows that ARE delivered stay bit-identical to the generator engine.
from repro.sweep import (DEFAULT_TENANT, DesignQuarantine,  # noqa: E402
                         FAULTED, FaultInjector, REJECTED, RetryPolicy,
                         SweepTimeoutError, TIMED_OUT)


def test_fault_injector_is_deterministic_per_site():
    """Same seed + same plan => same firing pattern, independent of how
    often OTHER sites are drawn in between."""
    a = FaultInjector(seed=7).arm("shard.fault", rate=0.3)
    b = FaultInjector(seed=7).arm("shard.fault", rate=0.3)
    fired_a = [a.draw("shard.fault") for _ in range(40)]
    fired_b = []
    for _ in range(40):
        b.draw("shard.hang")             # interleaved draws at other sites
        fired_b.append(b.draw("shard.fault"))
        b.draw("pool.broken")
    assert fired_a == fired_b and any(fired_a)
    # keyed arms scope to one design: other keys never fire
    c = FaultInjector(seed=7).arm("shard.fault", rate=1.0, key="poisoned")
    assert not c.draw("shard.fault", key="clean")
    assert c.draw("shard.fault", key="poisoned")


def test_retry_policy_backoff_caps():
    p = RetryPolicy(max_attempts=4, backoff_s=0.01, backoff_mult=4.0,
                    max_backoff_s=0.05)
    assert p.backoff(0) == pytest.approx(0.01)
    assert p.backoff(1) == pytest.approx(0.04)
    assert p.backoff(2) == pytest.approx(0.05)   # capped


def test_quarantine_trips_and_cooldown_resets():
    q = DesignQuarantine(threshold=2)
    assert not q.strike("k", "first")
    assert q.strike("k", "second")               # trips on the 2nd strike
    assert q.is_quarantined("k") and "second" in q.reason("k")
    assert not q.is_quarantined("other")
    q.reset("k")
    assert not q.is_quarantined("k")
    qc = DesignQuarantine(threshold=1, cooldown_s=0.0)
    qc.strike("k", "boom")
    assert not qc.is_quarantined("k")            # cooldown already elapsed


def test_transient_shard_fault_is_retried_bit_identical():
    """One injected shard fault, absorbed by the retry policy: verdicts
    identical to the fault-free run, zero rows lost."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    D = np.array([[1], [2], [4], [8]])
    ref = resimulate_batch(base, D)
    inj = FaultInjector(seed=3).arm("shard.fault", at=[0])
    with _manual_service(block=8, injector=inj,
                         retry=RetryPolicy(max_attempts=3,
                                           backoff_s=0.0)) as svc:
        out = svc.sweep(builder(), D)
    _assert_outcome_equal(out, ref, "transient fault")
    st = svc.scheduler.stats()
    assert st["retries"] >= 1 and st["faulted_rows"] == 0
    assert inj.stats()["fired"]["shard.fault"] == 1


def test_retry_exhaustion_faults_only_that_shard():
    """A persistently faulting shard fails ITS rows (FAULTED, definite,
    with a reason) while the surviving shard's rows deliver exactly."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    D = np.array([[1], [2], [3], [4], [5], [6], [7], [8]])  # sorted unique
    ref = resimulate_batch(base, D)
    # launch draws: chunk0 -> #0, chunk1 -> #1; chunk0's retry -> #2
    inj = FaultInjector(seed=3).arm("shard.fault", at=[0, 2])
    with _manual_service(block=8, shards=2, min_shard_rows=1, injector=inj,
                         retry=RetryPolicy(max_attempts=2,
                                           backoff_s=0.0)) as svc:
        out = svc.sweep(builder(), D)
    assert (out.status[:4] == FAULTED).all()
    assert (out.cycles[:4] == -1).all()
    for k in range(4):
        assert "attempts" in out.reasons[k], out.reasons[k]
    assert (out.status[4:] == ref.status[4:]).all()
    assert (out.cycles[4:] == ref.cycles[4:]).all()
    st = svc.scheduler.stats()
    assert st["faulted_rows"] == 4 and st["retries"] == 1
    assert svc.quarantine.stats()["strikes"] == 1


def test_shard_corruption_detected_and_retried():
    """A shard returning malformed arrays must never deliver wrong
    verdicts: host-side validation treats it as a retryable fault."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    D = np.array([[1], [2], [4], [8]])
    ref = resimulate_batch(base, D)
    inj = FaultInjector(seed=11).arm("shard.corrupt", at=[0])
    with _manual_service(block=8, injector=inj,
                         retry=RetryPolicy(max_attempts=3,
                                           backoff_s=0.0)) as svc:
        out = svc.sweep(builder(), D)
    _assert_outcome_equal(out, ref, "corruption retried")
    assert svc.scheduler.stats()["retries"] >= 1


def test_hung_shard_times_out_under_deadline():
    """A hung worker cannot hang the client: the deadline bounds the wait
    and every undelivered row terminates TIMED_OUT."""
    builder = lambda: producer_consumer(n=32, depth=4)
    D = np.array([[1], [2], [3], [4], [5], [6], [7], [8]])
    inj = FaultInjector(seed=5, hang_s=5.0).arm("shard.hang", at=[0])
    with _manual_service(block=8, shards=2, min_shard_rows=1,
                         injector=inj,
                         retry=RetryPolicy(max_attempts=2,
                                           backoff_s=0.0)) as svc:
        h = svc.submit(builder(), D, deadline_s=0.2)
        while svc.step():
            pass
        out = h.result()
    assert (out.status == TIMED_OUT).all()
    assert all("deadline" in r or "timed out" in r for r in out.reasons)
    assert svc.scheduler.stats()["timed_out_rows"] >= len(D)


def test_deadline_expired_before_scheduling_fails_fast():
    builder = lambda: producer_consumer(n=32, depth=4)
    with _manual_service(block=4) as svc:
        h = svc.submit(builder(), np.array([[2], [4]]), deadline_s=0.0)
        _time_spin()
        while svc.step():
            pass
        out = h.result()
    assert (out.status == TIMED_OUT).all()
    assert "before this config was scheduled" in out.reasons[0]


def _time_spin():
    import time
    t0 = time.perf_counter()
    while time.perf_counter() <= t0:
        pass


def test_injected_pool_breakage_respawns_and_delivers():
    """An injected broken pool triggers one bounded respawn; the block
    still delivers bit-identically."""
    builder = lambda: skynet_like(items=48, depth=6)
    base = simulate(builder())
    rng = np.random.default_rng(7)
    D = rng.integers(1, 13, size=(8, len(base.depths)))
    ref = resimulate_batch(base, D)
    inj = FaultInjector(seed=9).arm("pool.broken", at=[0])
    with _manual_service(block=8, shards=2, min_shard_rows=1,
                         injector=inj) as svc:
        out = svc.sweep(builder(), D)
    assert (out.status == ref.status).all()
    assert (out.cycles == ref.cycles).all()
    assert svc.scheduler.stats()["pool_respawns"] == 1


def test_pool_respawn_budget_exhaustion_fails_definite():
    """When the pool keeps breaking past the respawn budget, rows fail
    FAULTED with a reason — never a hang, never a crash."""
    builder = lambda: producer_consumer(n=32, depth=4)
    D = np.array([[1], [2], [3], [4]])
    inj = FaultInjector(seed=2).arm("pool.broken", rate=1.0)
    with _manual_service(block=4, shards=2, min_shard_rows=1,
                         max_pool_respawns=0, injector=inj) as svc:
        out = svc.sweep(builder(), D)
    assert (out.status == FAULTED).all()
    assert all("respawn budget" in r for r in out.reasons)


def test_quarantine_fails_queued_rows_and_rejects_resubmits():
    """Striking past the threshold trips the design's circuit breaker:
    queued same-design rows fail fast and new submits are refused at the
    front door, while a clean design keeps being served; reset restores."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    clean_builder = lambda: producer_consumer(n=16, depth=2)
    clean_base = simulate(clean_builder())
    ref_clean = resimulate_batch(clean_base, np.array([[2]]))
    inj = FaultInjector(seed=4).arm("shard.fault", at=[0])
    with _manual_service(block=1, injector=inj, quarantine_after=1,
                         retry=RetryPolicy(max_attempts=1,
                                           backoff_s=0.0)) as svc:
        hA = svc.submit(base, np.array([[2]]))
        hB = svc.submit(base, np.array([[4]]))
        while svc.step():
            pass
        outA, outB = hA.result(), hB.result()
        assert (outA.status == FAULTED).all()
        assert (outB.status == FAULTED).all()
        assert "quarantined" in outB.reasons[0]
        # front door refuses the poisoned design...
        hC = svc.submit(base, np.array([[8]]))
        assert hC.rejected
        outC = hC.result()
        assert (outC.status == REJECTED).all()
        assert "quarantined" in outC.reasons[0]
        # ...while a clean design is served normally
        outClean = svc.sweep(clean_base, np.array([[2]]))
        _assert_outcome_equal(outClean, ref_clean, "clean design")
        # reset gives the design a fresh budget (injector plan is spent)
        svc.quarantine.reset()
        outD = svc.sweep(base, np.array([[2]]))
        ref = resimulate_batch(base, np.array([[2]]))
        _assert_outcome_equal(outD, ref, "after reset")
    assert svc.quarantine.stats()["trips"] == 1


def test_admission_quota_rejects_then_releases():
    """Per-tenant quota: excess rows are shed with a definite REJECTED
    verdict; finishing a sweep releases its reservation."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    D3 = np.array([[1], [2], [4]])
    ref = resimulate_batch(base, D3)
    with _manual_service(block=8,
                         max_inflight_rows_per_tenant=4) as svc:
        h1 = svc.submit(base, D3, tenant="alice")
        h2 = svc.submit(base, D3, tenant="alice")      # 3+3 > 4: shed
        h3 = svc.submit(base, D3, tenant="bob")        # other tenant: fine
        assert not h1.rejected and h2.rejected and not h3.rejected
        out2 = h2.result()                             # immediate, no hang
        assert (out2.status == REJECTED).all()
        assert "quota" in out2.reasons[0]
        assert svc.admission.inflight("alice") == 3
        while svc.step():
            pass
        _assert_outcome_equal(h1.result(), ref, "admitted tenant")
        _assert_outcome_equal(h3.result(), ref, "other tenant")
        # completion released the reservation: same tenant admits again
        assert svc.admission.inflight("alice") == 0
        h4 = svc.submit(base, D3, tenant="alice")
        assert not h4.rejected
        while svc.step():
            pass
        _assert_outcome_equal(h4.result(), ref, "after release")
        st = svc.admission.stats()
        assert st["rejected_requests"] == 1 and st["rejected_rows"] == 3


def test_queue_depth_load_shedding():
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    with _manual_service(block=8, max_queued_rows=4) as svc:
        h1 = svc.submit(base, np.array([[1], [2], [4]]), tenant="a")
        h2 = svc.submit(base, np.array([[1], [2], [4]]), tenant="b")
        assert not h1.rejected and h2.rejected
        assert "load shed" in h2.result().reasons[0]
        while svc.step():
            pass
        assert h1.result().ok.any()


def test_cancellation_releases_admission_reservation():
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    with _manual_service(block=8,
                         max_inflight_rows_per_tenant=4) as svc:
        h1 = svc.submit(base, np.array([[1], [2], [4]]), tenant="a")
        h1.cancel()
        while svc.step():
            pass
        h1.result()
        assert svc.admission.inflight("a") == 0


def test_close_drains_inflight_and_fails_queued():
    """close(drain=True): a sweep with rows already in completed blocks
    finishes its remaining rows; one that never reached a block fails
    loudly.  Either way no stream hangs."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    D = np.array([[1], [2], [4], [8]])
    ref = resimulate_batch(base, D)
    with _manual_service(block=2) as svc:
        h1 = svc.submit(base, D)
        assert svc.step()                    # 2 of 4 rows delivered
        h2 = svc.submit(base, np.array([[16]]))
        svc.close(drain=True)
        _assert_outcome_equal(h1.result(), ref, "drained to completion")
        with pytest.raises(RuntimeError, match="service closed"):
            h2.result()


def test_stream_timeout_is_descriptive_and_resumable():
    """stream(timeout=) raises SweepTimeoutError (request id + progress),
    not a bare queue.Empty; the handle keeps working afterwards."""
    builder = lambda: producer_consumer(n=32, depth=4)
    base = simulate(builder())
    D = np.array([[1], [2], [4]])
    ref = resimulate_batch(base, D)
    with _manual_service(block=8) as svc:
        h = svc.submit(base, D)
        with pytest.raises(SweepTimeoutError) as ei:
            next(iter(h.stream(timeout=0.01)))
        assert ei.value.request_id == h.request_id
        assert ei.value.delivered == 0 and ei.value.total == 3
        assert "0/3" in str(ei.value) and "resume" in str(ei.value)
        while svc.step():                    # handle is still live
            pass
        _assert_outcome_equal(h.result(), ref, "resumed after timeout")


def test_acceptance_faulty_run_definite_and_clean_tenant_exact():
    """ISSUE 6 acceptance: under a seeded injector faulting one bulk
    tenant's design, no stream hangs, every row of every request ends in
    a definite status, and the clean tenant's rows are bit-identical."""
    bulk_builder = lambda: skynet_like(items=48, depth=6)
    bulk_base = simulate(bulk_builder())
    live_builder = lambda: producer_consumer(n=32, depth=4)
    live_base = simulate(live_builder())
    rng = np.random.default_rng(13)
    Db = rng.integers(1, 13, size=(20, len(bulk_base.depths)))
    Dl = np.array([[1], [2], [4], [8]])
    ref_b = resimulate_batch(bulk_base, Db)
    ref_l = resimulate_batch(live_base, Dl)
    inj = FaultInjector(seed=5)
    with _manual_service(block=4, quarantine_after=100, injector=inj,
                         retry=RetryPolicy(max_attempts=2,
                                           backoff_s=0.0)) as svc:
        bulk_key = svc.warm(bulk_base).key
        # draw #0 is the interactive tenant's single block; the bulk
        # blocks draw from #1 on.  Plan: bulk block #2 faults on both its
        # attempts (draws 2 and 3) and exhausts the 2-attempt budget.
        inj.arm("shard.fault", at=[2, 3], key=bulk_key)
        hb = svc.submit(bulk_base, Db, tenant="bulk", priority=BULK)
        hl = svc.submit(live_base, Dl, tenant="live")
        while svc.step():
            pass
        out_b, out_l = hb.result(), hl.result()
    # the clean tenant is untouched by the other tenant's faults
    _assert_outcome_equal(out_l, ref_l, "clean tenant")
    # the faulted tenant: every row definite; delivered rows exact
    assert inj.stats()["fired"]["shard.fault"] >= 1
    assert (out_b.status != CANCELLED).all()
    faulted = out_b.status == FAULTED
    assert faulted.any(), "seeded plan should exhaust at least one retry"
    assert (out_b.status[~faulted] == ref_b.status[~faulted]).all()
    assert (out_b.cycles[~faulted] == ref_b.cycles[~faulted]).all()
    assert (out_b.cycles[faulted] == -1).all()


# ------------------------------------------------------- real-pool drills
@pytest.mark.faults
def test_process_pool_blob_reship_and_bit_identity():
    """mode="process": freshly spawned workers pull each design's graph
    through the need-blob round trip once, then stay warm — results
    bit-identical to the library path."""
    builder = lambda: skynet_like(items=48, depth=6)
    base = simulate(builder())
    rng = np.random.default_rng(3)
    D = rng.integers(1, 13, size=(16, len(base.depths)))
    ref = resimulate_batch(base, D)
    with _manual_service(block=16, shards=2, mode="process",
                         min_shard_rows=1) as svc:
        svc.warm(base)
        out = svc.sweep(base, D)
    assert (out.status == ref.status).all()
    assert (out.cycles == ref.cycles).all()
    assert svc.scheduler.stats()["blob_reships"] >= 1


@pytest.mark.faults
def test_process_pool_killed_worker_respawns_and_recovers():
    """A worker hard-exiting breaks the real ProcessPoolExecutor; the
    scheduler respawns it (warm, via the pool initializer) and the sweep
    still delivers bit-identically."""
    import os as _os
    builder = lambda: skynet_like(items=48, depth=6)
    base = simulate(builder())
    rng = np.random.default_rng(3)
    D = rng.integers(1, 13, size=(16, len(base.depths)))
    ref = resimulate_batch(base, D)
    with _manual_service(block=16, shards=2, mode="process",
                         min_shard_rows=1, shard_timeout_s=30.0) as svc:
        svc.warm(base)
        # prime the blob registry so the respawned pool starts warm
        h0 = svc.submit(base, D[:2])
        while svc.step():
            pass
        h0.result()
        svc.scheduler._pool.submit(_os._exit, 11)   # murder a worker
        out = svc.sweep(base, D)
    assert (out.status == ref.status).all()
    assert (out.cycles == ref.cycles).all()
    assert svc.scheduler.stats()["pool_respawns"] >= 1
